//! detlint rule set: determinism & accounting checks over lexed tokens.
//!
//! Five rules (R1–R5) plus the `allow-audit` meta-rule emitted by the
//! directive parser and the engine:
//!
//! | id                          | guards                                        |
//! |-----------------------------|-----------------------------------------------|
//! | `hash-container`            | no HashMap/HashSet on the replay/result path  |
//! | `salt-registry`             | RNG salts live in `util::salts`, documented,  |
//! |                             | globally unique                               |
//! | `wall-clock`                | no Instant/SystemTime/ambient RNG in sim code |
//! | `unordered-float-reduction` | no float sum/fold over hash iteration         |
//! | `unchecked-cast`            | no bare `as` casts in byte/bandwidth/GPU-hour |
//! |                             | accounting (use `util::cast`)                 |
//! | `allow-audit`               | every suppression is well-formed, reasoned,   |
//! |                             | names a real rule, and suppresses something   |
//!
//! Suppression: an `allow(rule-id, "reason")` comment directive with the
//! `detlint::` prefix — trailing on the offending line, or standalone on
//! the line immediately above (applies to the next code line). See
//! `docs/detlint.md` for the full catalog and exact syntax.
//!
//! The semantics here are mirrored by a dependency-free Python twin used to
//! pre-verify the tree in containers without a Rust toolchain; behavioural
//! changes must land in both.

use super::lexer::{in_regions, Comment, Tok, TokKind};

/// Every valid rule id, in report order. Allow directives naming anything
/// else are themselves findings.
pub const RULE_IDS: [&str; 6] = [
    "hash-container",
    "salt-registry",
    "wall-clock",
    "unordered-float-reduction",
    "unchecked-cast",
    "allow-audit",
];

/// The one file allowed to define RNG salt constants (R2).
pub const REGISTRY_PATH: &str = "rust/src/util/salts.rs";
/// The one module allowed to contain `as` casts in accounting code (R5) —
/// it wraps them in debug-asserted helpers.
pub const CAST_MODULE: &str = "rust/src/util/cast.rs";
/// Files allowed to touch wall clocks / ambient entropy (R3): the bench
/// harness measures real elapsed time, and the CLI seeds from the
/// environment on request.
pub const R3_ALLOW: [&str; 2] = ["rust/src/util/bench.rs", "rust/src/main.rs"];
/// Directory prefixes with the same R3 exemption (harness/driver code).
pub const R3_ALLOW_DIRS: [&str; 3] = ["tools/", "benches/", "examples/"];

const INT_TYPES: [&str; 12] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];
/// Accounting vocabulary: an `as <int>` cast whose statement mentions an
/// identifier containing one of these substrings is accounting arithmetic.
const VOCAB: [&str; 3] = ["bytes", "bps", "gpu_hour"];
const CLOCK_TOKENS: [&str; 6] =
    ["Instant", "SystemTime", "UNIX_EPOCH", "thread_rng", "OsRng", "from_entropy"];

/// Salt-family hex literal (`0xA271_…`, `0xA272_…`, `0xFA0…`), case
/// insensitive. Matching literals may only appear in the registry.
fn is_salt_family(text: &str) -> bool {
    let u = text.to_ascii_uppercase();
    u.starts_with("0XA271_") || u.starts_with("0XA272_") || u.starts_with("0XFA0")
}

/// One lint finding. `suppressed` carries the written reason when an
/// allow directive covered it.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
    pub suggestion: &'static str,
    pub suppressed: Option<String>,
}

impl Finding {
    fn new(rule: &'static str, file: &str, line: u32, message: String) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line,
            message,
            suggestion: suggestion_for(rule),
            suppressed: None,
        }
    }
}

/// Per-rule remediation hint, attached to every finding.
pub fn suggestion_for(rule: &str) -> &'static str {
    match rule {
        "hash-container" => {
            "use BTreeMap/BTreeSet (or an indexed Vec), or annotate why hash \
             order cannot reach a result"
        }
        "salt-registry" => {
            "define the salt once in util::salts with a doc comment and a \
             unique value, and import it"
        }
        "wall-clock" => {
            "derive times from the simulated clock and randomness from the \
             seeded util::rng stream"
        }
        "unordered-float-reduction" => {
            "collect into a sorted container (or switch the map to BTreeMap) \
             before reducing floats"
        }
        "unchecked-cast" => "use the debug-asserted helpers in util::cast",
        "allow-audit" => {
            "write detlint::allow(rule-id, \"reason\") with a real rule id \
             and a non-empty reason, and delete stale allows"
        }
        _ => "",
    }
}

/// A parsed allow directive: which rule it suppresses, on which line, why.
#[derive(Clone, Debug)]
pub struct Allow {
    pub line: u32,
    pub rule: String,
    pub reason: String,
    /// Line this allow suppresses: its own line for a trailing comment, the
    /// next code line for a standalone one (0 = nothing follows).
    pub target: u32,
    pub used: bool,
}

/// A salt constant declaration, collected tree-wide for the R2 finish pass.
#[derive(Clone, Debug)]
pub struct SaltDecl {
    pub file: String,
    pub line: u32,
    pub name: String,
    pub value: Option<String>,
    pub registry: bool,
    pub doc: bool,
}

/// Everything a per-file rule pass needs.
pub struct FileCtx<'a> {
    pub path: &'a str,
    pub toks: &'a [Tok],
    pub comments: &'a [Comment],
    pub tests: &'a [(u32, u32)],
    pub is_src: bool,
}

/// One lint rule. `check` runs per file; `salts` is the tree-wide R2
/// accumulator (only the salt-registry rule writes it).
pub trait Rule {
    fn id(&self) -> &'static str;
    fn description(&self) -> &'static str;
    fn check(&self, ctx: &FileCtx<'_>, salts: &mut Vec<SaltDecl>, out: &mut Vec<Finding>);
}

/// The full rule set, in report order.
pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(HashContainer),
        Box::new(SaltRegistry),
        Box::new(WallClock),
        Box::new(UnorderedFloatReduction),
        Box::new(UncheckedCast),
    ]
}

/// Token index range of the statement-ish context around `idx`: from the
/// token after the nearest preceding `;`/`{`/`}` to the nearest following
/// one (inclusive).
fn stmt_bounds(toks: &[Tok], idx: usize) -> (usize, usize) {
    let mut lo = idx;
    while lo > 0 && !is_stmt_edge(&toks[lo - 1].text) {
        lo -= 1;
    }
    let mut hi = idx;
    let n = toks.len();
    while hi < n - 1 && !is_stmt_edge(&toks[hi].text) {
        hi += 1;
    }
    (lo, hi)
}

fn is_stmt_edge(text: &str) -> bool {
    text == ";" || text == "{" || text == "}"
}

/// Is the token at `idx` part of a `use` statement? Scans back to the
/// previous `;` only — a use-group's `{` must not truncate the search, and
/// every use statement ends in `;`, so the scan can never leak across one
/// into an expression context.
fn in_use_stmt(toks: &[Tok], idx: usize) -> bool {
    let mut j = idx;
    while j > 0 {
        j -= 1;
        if toks[j].text == ";" {
            return false;
        }
        if toks[j].text == "use" {
            return true;
        }
    }
    false
}

/// Parse one `(rule, "reason")` suffix starting at byte `j` (just past an
/// occurrence of the directive needle). Whitespace is allowed everywhere
/// the grammar shows it; the reason may not contain a quote.
fn parse_allow_after(b: &[u8], mut j: usize) -> Option<(String, String)> {
    if b.get(j) != Some(&b'(') {
        return None;
    }
    j += 1;
    while b.get(j).is_some_and(|c| c.is_ascii_whitespace()) {
        j += 1;
    }
    let rule_start = j;
    while b
        .get(j)
        .is_some_and(|c| c.is_ascii_alphanumeric() || *c == b'_' || *c == b'-')
    {
        j += 1;
    }
    if j == rule_start {
        return None;
    }
    let rule = String::from_utf8_lossy(&b[rule_start..j]).into_owned();
    while b.get(j).is_some_and(|c| c.is_ascii_whitespace()) {
        j += 1;
    }
    if b.get(j) != Some(&b',') {
        return None;
    }
    j += 1;
    while b.get(j).is_some_and(|c| c.is_ascii_whitespace()) {
        j += 1;
    }
    if b.get(j) != Some(&b'"') {
        return None;
    }
    j += 1;
    let reason_start = j;
    while j < b.len() && b[j] != b'"' {
        j += 1;
    }
    if j >= b.len() {
        return None;
    }
    let reason = String::from_utf8_lossy(&b[reason_start..j]).into_owned();
    j += 1;
    while b.get(j).is_some_and(|c| c.is_ascii_whitespace()) {
        j += 1;
    }
    if b.get(j) != Some(&b')') {
        return None;
    }
    Some((rule, reason))
}

/// Extract allow directives from a file's comments. Malformed and
/// empty-reason directives become `allow-audit` findings immediately.
pub fn parse_allows(
    path: &str,
    comments: &[Comment],
    toks: &[Tok],
    findings: &mut Vec<Finding>,
) -> Vec<Allow> {
    let mut code_lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
    code_lines.sort_unstable();
    code_lines.dedup();
    let mut allows = Vec::new();
    const NEEDLE: &str = "detlint::allow";
    for c in comments {
        if !c.text.contains(NEEDLE) {
            continue;
        }
        let b = c.text.as_bytes();
        let mut parsed = Vec::new();
        let mut raw_count = 0usize;
        let mut pos = 0usize;
        while let Some(k) = c.text[pos..].find(NEEDLE) {
            let at = pos + k;
            raw_count += 1;
            if let Some(pair) = parse_allow_after(b, at + NEEDLE.len()) {
                parsed.push(pair);
            }
            pos = at + NEEDLE.len();
        }
        if parsed.len() != raw_count {
            findings.push(Finding::new(
                "allow-audit",
                path,
                c.line,
                "malformed detlint::allow directive (expected detlint::allow(rule-id, \
                 \"reason\"))"
                    .to_string(),
            ));
        }
        for (rule, reason) in parsed {
            if reason.trim().is_empty() {
                findings.push(Finding::new(
                    "allow-audit",
                    path,
                    c.line,
                    format!("allow({rule}) carries an empty reason"),
                ));
                continue;
            }
            let target = if c.trailing {
                c.line
            } else {
                code_lines.iter().copied().find(|&l| l > c.line).unwrap_or(0)
            };
            allows.push(Allow { line: c.line, rule, reason, target, used: false });
        }
    }
    allows
}

/// R1: HashMap/HashSet on the replay/result path.
pub struct HashContainer;

impl Rule for HashContainer {
    fn id(&self) -> &'static str {
        "hash-container"
    }
    fn description(&self) -> &'static str {
        "hash containers have a randomized-feeling (build-dependent) iteration \
         order; replay/result code must use ordered containers"
    }
    fn check(&self, ctx: &FileCtx<'_>, _salts: &mut Vec<SaltDecl>, out: &mut Vec<Finding>) {
        if !ctx.is_src {
            return;
        }
        for (i, t) in ctx.toks.iter().enumerate() {
            if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
                if in_regions(ctx.tests, t.line) || in_use_stmt(ctx.toks, i) {
                    continue;
                }
                out.push(Finding::new(
                    self.id(),
                    ctx.path,
                    t.line,
                    format!("{} on the replay/result path", t.text),
                ));
            }
        }
    }
}

/// R2: RNG salt constants live in the registry, once each.
pub struct SaltRegistry;

impl Rule for SaltRegistry {
    fn id(&self) -> &'static str {
        "salt-registry"
    }
    fn description(&self) -> &'static str {
        "RNG domain-separation salts are declared once, documented, and \
         globally unique in util::salts"
    }
    fn check(&self, ctx: &FileCtx<'_>, salts: &mut Vec<SaltDecl>, out: &mut Vec<Finding>) {
        let toks = ctx.toks;
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokKind::Ident || !t.text.starts_with("SALT_") {
                continue;
            }
            if i >= 1 && toks[i - 1].text == "const" {
                let mut val = None;
                for tj in toks.iter().take((i + 8).min(toks.len())).skip(i + 1) {
                    if tj.kind == TokKind::Num {
                        val = Some(tj.text.clone());
                        break;
                    }
                }
                salts.push(SaltDecl {
                    file: ctx.path.to_string(),
                    line: t.line,
                    name: t.text.clone(),
                    value: val,
                    registry: ctx.path == REGISTRY_PATH,
                    doc: false,
                });
                if ctx.path != REGISTRY_PATH {
                    out.push(Finding::new(
                        self.id(),
                        ctx.path,
                        t.line,
                        format!("salt constant {} declared outside util::salts", t.text),
                    ));
                }
            } else if ctx.path == REGISTRY_PATH
                && i + 2 < toks.len()
                && toks[i + 1].text == "="
                && toks[i + 2].kind == TokKind::Num
            {
                // Registry macro entry `SALT_X = <num>`: doc comment required
                // on the immediately preceding line.
                let doc = ctx.comments.iter().any(|c| c.doc && c.line + 1 == t.line);
                salts.push(SaltDecl {
                    file: ctx.path.to_string(),
                    line: t.line,
                    name: t.text.clone(),
                    value: Some(toks[i + 2].text.clone()),
                    registry: true,
                    doc,
                });
            }
        }
        if ctx.path != REGISTRY_PATH {
            for t in toks {
                if t.kind == TokKind::Num && is_salt_family(&t.text) {
                    out.push(Finding::new(
                        self.id(),
                        ctx.path,
                        t.line,
                        format!("salt-family literal {} outside util::salts", t.text),
                    ));
                }
            }
        }
    }
}

/// R3: wall-clock reads and ambient entropy in simulation code.
pub struct WallClock;

impl Rule for WallClock {
    fn id(&self) -> &'static str {
        "wall-clock"
    }
    fn description(&self) -> &'static str {
        "simulation results must be a pure function of (seed, identity); real \
         clocks and OS entropy belong only to the bench/driver harness"
    }
    fn check(&self, ctx: &FileCtx<'_>, _salts: &mut Vec<SaltDecl>, out: &mut Vec<Finding>) {
        if R3_ALLOW.contains(&ctx.path) || R3_ALLOW_DIRS.iter().any(|d| ctx.path.starts_with(d)) {
            return;
        }
        for t in ctx.toks {
            if t.kind == TokKind::Ident && CLOCK_TOKENS.contains(&t.text.as_str()) {
                out.push(Finding::new(
                    self.id(),
                    ctx.path,
                    t.line,
                    format!("{}: wall-clock/ambient entropy in a sim path", t.text),
                ));
            }
        }
    }
}

/// R4: float reductions over unordered (hash) iteration.
pub struct UnorderedFloatReduction;

impl Rule for UnorderedFloatReduction {
    fn id(&self) -> &'static str {
        "unordered-float-reduction"
    }
    fn description(&self) -> &'static str {
        "float addition is not associative; summing over hash-order iteration \
         makes the result build-dependent"
    }
    fn check(&self, ctx: &FileCtx<'_>, _salts: &mut Vec<SaltDecl>, out: &mut Vec<Finding>) {
        if !ctx.is_src {
            return;
        }
        let toks = ctx.toks;
        // Pass 1: names bound to hash containers in this file, via
        // `let [mut] NAME … HashMap` or `NAME: HashMap<..>` ascriptions.
        let mut hash_idents: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for (i, t) in toks.iter().enumerate() {
            if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
                continue;
            }
            let (lo, hi) = stmt_bounds(toks, i);
            if toks[lo].text == "let" {
                let mut k = lo + 1;
                if k <= hi && toks[k].text == "mut" {
                    k += 1;
                }
                if k <= hi && toks[k].kind == TokKind::Ident {
                    hash_idents.insert(toks[k].text.clone());
                }
            } else if i >= 1 {
                let stop = lo.max(1);
                let mut j = i - 1;
                while j >= stop {
                    if toks[j].text == ":" && j - 1 >= lo && toks[j - 1].kind == TokKind::Ident {
                        hash_idents.insert(toks[j - 1].text.clone());
                        break;
                    }
                    j -= 1;
                }
            }
        }
        // Pass 2: NAME.values()/keys()/iter() … sum/product/fold before `;`.
        for (i, t) in toks.iter().enumerate() {
            let calls_iter = t.kind == TokKind::Ident
                && hash_idents.contains(&t.text)
                && i + 2 < toks.len()
                && toks[i + 1].text == "."
                && matches!(toks[i + 2].text.as_str(), "values" | "keys" | "iter");
            if !calls_iter || in_regions(ctx.tests, t.line) {
                continue;
            }
            let mut j = i + 2;
            while j < toks.len() && toks[j].text != ";" {
                if toks[j].kind == TokKind::Ident
                    && matches!(toks[j].text.as_str(), "sum" | "product" | "fold")
                {
                    out.push(Finding::new(
                        self.id(),
                        ctx.path,
                        t.line,
                        format!("float reduction over unordered iteration of `{}`", t.text),
                    ));
                    break;
                }
                j += 1;
            }
        }
    }
}

/// R5: bare `as` casts in accounting arithmetic.
pub struct UncheckedCast;

impl Rule for UncheckedCast {
    fn id(&self) -> &'static str {
        "unchecked-cast"
    }
    fn description(&self) -> &'static str {
        "`as` silently truncates/wraps; byte, bandwidth, and GPU-hour \
         arithmetic must go through the debug-asserted util::cast helpers"
    }
    fn check(&self, ctx: &FileCtx<'_>, _salts: &mut Vec<SaltDecl>, out: &mut Vec<Finding>) {
        if !ctx.is_src || ctx.path == CAST_MODULE {
            return;
        }
        let toks = ctx.toks;
        for (i, t) in toks.iter().enumerate() {
            let is_int_cast = t.kind == TokKind::Ident
                && t.text == "as"
                && i + 1 < toks.len()
                && INT_TYPES.contains(&toks[i + 1].text.as_str());
            if !is_int_cast || in_regions(ctx.tests, t.line) {
                continue;
            }
            let (lo, hi) = stmt_bounds(toks, i);
            let mut vocab_hit = None;
            for tj in &toks[lo..=hi] {
                if tj.kind == TokKind::Ident {
                    let lower = tj.text.to_ascii_lowercase();
                    if VOCAB.iter().any(|v| lower.contains(v)) {
                        vocab_hit = Some(tj.text.clone());
                        break;
                    }
                }
            }
            if let Some(hit) = vocab_hit {
                out.push(Finding::new(
                    self.id(),
                    ctx.path,
                    t.line,
                    format!("`as {}` in accounting arithmetic (near `{hit}`)", toks[i + 1].text),
                ));
            }
        }
    }
}

fn parse_int(s: &str) -> Option<u128> {
    let t: String = s.chars().filter(|&c| c != '_').collect();
    if let Some(h) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        u128::from_str_radix(h, 16).ok()
    } else if let Some(o) = t.strip_prefix("0o") {
        u128::from_str_radix(o, 8).ok()
    } else if let Some(b2) = t.strip_prefix("0b") {
        u128::from_str_radix(b2, 2).ok()
    } else {
        t.parse().ok()
    }
}

/// Tree-wide R2 finish pass: duplicate salt values and undocumented
/// registry entries. Groups keep first-seen order so reports are stable.
pub fn finish_salts(salts: &[SaltDecl], findings: &mut Vec<Finding>) {
    let mut vals: Vec<u128> = Vec::new();
    let mut groups: Vec<Vec<&SaltDecl>> = Vec::new();
    for d in salts {
        let v = match d.value.as_deref().and_then(parse_int) {
            Some(v) => v,
            None => continue,
        };
        match vals.iter().position(|&x| x == v) {
            Some(k) => groups[k].push(d),
            None => {
                vals.push(v);
                groups.push(vec![d]);
            }
        }
    }
    for (v, ds) in vals.iter().zip(&groups) {
        if ds.len() > 1 {
            for d in ds {
                findings.push(Finding::new(
                    "salt-registry",
                    &d.file,
                    d.line,
                    format!("duplicate salt value {v:#x} ({})", d.name),
                ));
            }
        }
    }
    for d in salts {
        if d.registry && !d.doc {
            findings.push(Finding::new(
                "salt-registry",
                &d.file,
                d.line,
                format!("registry salt {} has no doc comment", d.name),
            ));
        }
    }
}
