//! `detlint` — in-tree determinism & accounting static analysis.
//!
//! The replay core promises that every simulated quantity is a pure
//! function of (seed, identity). That promise is easy to break silently:
//! a `HashMap` iteration order reaching a result, a wall-clock read, a
//! salt constant duplicated under two names, a float sum over unordered
//! iteration, or a quiet `as` truncation in byte accounting. This module
//! scans the repo's own Rust sources for those patterns with a lightweight
//! lexer ([`lexer`]) and a small rule engine ([`rules`]), and the
//! `detlint` binary (`tools/detlint.rs`) gates CI on the result.
//!
//! Structure: [`Analyzer`] accumulates per-file scans (so fixtures can
//! feed sources directly) plus tree-wide salt state; [`Analyzer::finish`]
//! runs the cross-file passes and yields a [`Report`] that renders as
//! human text or JSON. [`run_tree`] walks `rust/src`, `tools`, `benches`,
//! and `examples` in sorted order.
//!
//! A dependency-free Python twin of the lexer + rules is kept in lockstep
//! for pre-verifying the tree in containers without a Rust toolchain; see
//! `docs/detlint.md`.

pub mod lexer;
pub mod rules;

use crate::util::json::Json;
use rules::{all_rules, finish_salts, parse_allows, FileCtx, Finding, SaltDecl, RULE_IDS};
use std::io;
use std::path::{Path, PathBuf};

/// Incremental scan state: feed files with [`scan_source`], then call
/// [`finish`] for the cross-file passes and the final [`Report`].
///
/// [`scan_source`]: Analyzer::scan_source
/// [`finish`]: Analyzer::finish
pub struct Analyzer {
    rules: Vec<Box<dyn rules::Rule>>,
    salts: Vec<SaltDecl>,
    findings: Vec<Finding>,
    files: usize,
}

impl Default for Analyzer {
    fn default() -> Self {
        Self::new()
    }
}

impl Analyzer {
    pub fn new() -> Analyzer {
        Analyzer { rules: all_rules(), salts: Vec::new(), findings: Vec::new(), files: 0 }
    }

    /// Scan one file. `path` must be repo-relative with `/` separators —
    /// rule scoping (registry file, R3 allowlist, `rust/src/` gating) keys
    /// off it.
    pub fn scan_source(&mut self, path: &str, src: &str) {
        self.files += 1;
        let (toks, comments) = lexer::lex(src);
        let tests = lexer::test_regions(&toks);
        let mut allows = parse_allows(path, &comments, &toks, &mut self.findings);
        let ctx = FileCtx {
            path,
            toks: &toks,
            comments: &comments,
            tests: &tests,
            is_src: path.starts_with("rust/src/"),
        };
        let mut raw = Vec::new();
        for rule in &self.rules {
            rule.check(&ctx, &mut self.salts, &mut raw);
        }
        // Suppression pass: an allow matches on (rule, target line) and
        // covers every finding of that rule on the line.
        for mut f in raw {
            if let Some(a) =
                allows.iter_mut().find(|a| a.rule == f.rule && a.target == f.line)
            {
                a.used = true;
                f.suppressed = Some(a.reason.clone());
            }
            self.findings.push(f);
        }
        // Allow audit: unknown rule ids and allows that suppress nothing.
        for a in &allows {
            if !RULE_IDS.contains(&a.rule.as_str()) {
                let msg = format!("allow names unknown rule `{}`", a.rule);
                self.findings.push(audit(path, a.line, msg));
            } else if !a.used {
                let msg = format!("allow({}) suppresses nothing", a.rule);
                self.findings.push(audit(path, a.line, msg));
            }
        }
    }

    /// Run the tree-wide passes (salt uniqueness/documentation) and return
    /// the report.
    pub fn finish(mut self) -> Report {
        finish_salts(&self.salts, &mut self.findings);
        Report { findings: self.findings, files: self.files }
    }
}

fn audit(path: &str, line: u32, message: String) -> Finding {
    Finding {
        rule: "allow-audit",
        file: path.to_string(),
        line,
        message,
        suggestion: rules::suggestion_for("allow-audit"),
        suppressed: None,
    }
}

/// The outcome of a scan: all findings (suppressed ones carry their
/// reason) plus the file count.
pub struct Report {
    pub findings: Vec<Finding>,
    pub files: usize,
}

impl Report {
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.suppressed.is_none())
    }

    pub fn unsuppressed_count(&self) -> usize {
        self.unsuppressed().count()
    }

    pub fn suppressed_count(&self) -> usize {
        self.findings.len() - self.unsuppressed_count()
    }

    /// Human-readable report: one `file:line: [rule] message` block per
    /// unsuppressed finding (with a remediation hint), then a summary line.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in self.unsuppressed() {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
            if !f.suggestion.is_empty() {
                out.push_str(&format!("  hint: {}\n", f.suggestion));
            }
        }
        out.push_str(&format!(
            "-- {} unsuppressed, {} suppressed, {} files\n",
            self.unsuppressed_count(),
            self.suppressed_count(),
            self.files
        ));
        out
    }

    /// Machine-readable report (the CI artifact). Schema documented in
    /// `docs/detlint.md`.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("version", 1u64);
        root.set("files_scanned", self.files);
        root.set("unsuppressed", self.unsuppressed_count());
        root.set("suppressed", self.suppressed_count());
        root.set("rules", RULE_IDS.to_vec());
        let mut arr = Vec::with_capacity(self.findings.len());
        for f in &self.findings {
            let mut o = Json::obj();
            o.set("rule", f.rule)
                .set("file", f.file.as_str())
                .set("line", u64::from(f.line))
                .set("message", f.message.as_str())
                .set("suggestion", f.suggestion);
            if let Some(reason) = &f.suppressed {
                o.set("suppressed", reason.as_str());
            }
            arr.push(o);
        }
        root.set("findings", arr);
        root
    }
}

/// Scan roots, relative to the repo root.
pub const SCAN_ROOTS: [&str; 4] = ["rust/src", "tools", "benches", "examples"];

fn collect(dir: &Path, rel: &str, files: &mut Vec<(String, PathBuf)>) -> io::Result<()> {
    let mut names: Vec<(bool, String)> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let is_dir = entry.file_type()?.is_dir();
        if let Ok(name) = entry.file_name().into_string() {
            names.push((is_dir, name));
        }
    }
    names.sort();
    // Files of this directory first (sorted), then subdirectories — the
    // same order the Python twin's os.walk produces.
    for (_, name) in names.iter().filter(|(d, _)| !d) {
        if name.ends_with(".rs") {
            files.push((format!("{rel}/{name}"), dir.join(name)));
        }
    }
    for (_, name) in names.iter().filter(|(d, _)| *d) {
        collect(&dir.join(name), &format!("{rel}/{name}"), files)?;
    }
    Ok(())
}

/// Walk the scan roots under `root` and analyze every `.rs` file.
pub fn run_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    for rel in SCAN_ROOTS {
        let dir = root.join(rel);
        if dir.is_dir() {
            collect(&dir, rel, &mut files)?;
        }
    }
    let mut an = Analyzer::new();
    for (rel, abs) in &files {
        let src = std::fs::read_to_string(abs)?;
        an.scan_source(rel, &src);
    }
    Ok(an.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_one(path: &str, src: &str) -> Report {
        let mut an = Analyzer::new();
        an.scan_source(path, src);
        an.finish()
    }

    fn rules_of(report: &Report) -> Vec<&'static str> {
        report.unsuppressed().map(|f| f.rule).collect()
    }

    // ---- R1 hash-container ----

    #[test]
    fn r1_flags_hash_containers_in_src() {
        let r = scan_one(
            "rust/src/x.rs",
            "fn f() { let m: HashMap<u32, u32> = HashMap::new(); }",
        );
        assert_eq!(rules_of(&r), ["hash-container", "hash-container"]);
        assert_eq!(r.findings[0].line, 1);
    }

    #[test]
    fn r1_ignores_use_statements_tests_and_non_src() {
        let grouped_use = "use std::collections::{BTreeMap, HashMap};\nfn f() {}\n";
        assert_eq!(scan_one("rust/src/x.rs", grouped_use).unsuppressed_count(), 0);
        let in_test =
            "#[cfg(test)]\nmod tests {\n fn f() { let m = HashMap::new(); }\n}\n";
        assert_eq!(scan_one("rust/src/x.rs", in_test).unsuppressed_count(), 0);
        let tool = "fn f() { let m = HashMap::new(); }";
        assert_eq!(scan_one("tools/x.rs", tool).unsuppressed_count(), 0);
    }

    #[test]
    fn r1_trailing_allow_suppresses_and_is_consumed() {
        let src = "fn f() { let m = HashMap::new(); } \
                   // detlint::allow(hash-container, \"keyed access only\")\n";
        let r = scan_one("rust/src/x.rs", src);
        assert_eq!(r.unsuppressed_count(), 0);
        assert_eq!(r.suppressed_count(), 1);
        assert_eq!(r.findings[0].suppressed.as_deref(), Some("keyed access only"));
    }

    #[test]
    fn r1_standalone_allow_covers_next_code_line() {
        let src = "// detlint::allow(hash-container, \"scratch only\")\n\
                   fn f() { let m = HashMap::new(); }\n";
        let r = scan_one("rust/src/x.rs", src);
        assert_eq!(r.unsuppressed_count(), 0);
        assert_eq!(r.suppressed_count(), 1);
    }

    // ---- R2 salt-registry ----

    #[test]
    fn r2_flags_salt_const_outside_registry() {
        let r = scan_one("rust/src/x.rs", "const SALT_FOO: u64 = 0x1234;\n");
        assert_eq!(rules_of(&r), ["salt-registry"]);
        assert!(r.findings[0].message.contains("declared outside"));
    }

    #[test]
    fn r2_flags_salt_family_literal_outside_registry() {
        let r = scan_one("rust/src/x.rs", "fn f() -> u64 { 0xA272_0009 }\n");
        assert_eq!(rules_of(&r), ["salt-registry"]);
        assert!(r.findings[0].message.contains("salt-family literal"));
    }

    #[test]
    fn r2_duplicate_values_reported_for_each_decl() {
        let r = scan_one(
            "rust/src/x.rs",
            "const SALT_A: u64 = 0x7;\nconst SALT_B: u64 = 0x7;\n",
        );
        // Two outside-registry findings plus two duplicate-value findings.
        let dups: Vec<_> = r
            .unsuppressed()
            .filter(|f| f.message.contains("duplicate salt value 0x7"))
            .collect();
        assert_eq!(dups.len(), 2);
    }

    #[test]
    fn r2_registry_entry_requires_doc_comment() {
        let undocumented = "SALT_X = 0x9;\n";
        let r = scan_one(rules::REGISTRY_PATH, undocumented);
        assert_eq!(rules_of(&r), ["salt-registry"]);
        assert!(r.findings[0].message.contains("no doc comment"));
        let documented = "/// Domain: fixture.\nSALT_X = 0x9;\n";
        assert_eq!(scan_one(rules::REGISTRY_PATH, documented).unsuppressed_count(), 0);
    }

    // ---- R3 wall-clock ----

    #[test]
    fn r3_flags_clock_and_entropy_tokens() {
        let r = scan_one("rust/src/x.rs", "fn f() { let t = Instant::now(); }\n");
        assert_eq!(rules_of(&r), ["wall-clock"]);
        // R3 applies inside test modules too: timing asserts flake.
        let in_test = "#[cfg(test)]\nmod tests {\n fn f() { let t = SystemTime::now(); }\n}\n";
        assert_eq!(scan_one("rust/src/x.rs", in_test).unsuppressed_count(), 1);
    }

    #[test]
    fn r3_allowlists_harness_files_and_dirs() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(scan_one("rust/src/util/bench.rs", src).unsuppressed_count(), 0);
        assert_eq!(scan_one("rust/src/main.rs", src).unsuppressed_count(), 0);
        assert_eq!(scan_one("tools/x.rs", src).unsuppressed_count(), 0);
        assert_eq!(scan_one("benches/x.rs", src).unsuppressed_count(), 0);
        assert_eq!(scan_one("examples/x.rs", src).unsuppressed_count(), 0);
    }

    // ---- R4 unordered-float-reduction ----

    #[test]
    fn r4_flags_float_sum_over_hash_iteration() {
        let src =
            "fn f(m: HashMap<u64, f64>) -> f64 {\n    let s: f64 = m.values().sum();\n    s\n}\n";
        let r = scan_one("rust/src/x.rs", src);
        // R1 fires on the HashMap type too; look for the R4 finding.
        assert!(rules_of(&r).contains(&"unordered-float-reduction"));
        let f = r
            .unsuppressed()
            .find(|f| f.rule == "unordered-float-reduction")
            .unwrap();
        assert_eq!(f.line, 2);
        assert!(f.message.contains("`m`"));
    }

    #[test]
    fn r4_ignores_ordered_containers_and_non_reductions() {
        let vec_sum = "fn f(v: Vec<f64>) -> f64 { v.iter().sum() }\n";
        assert_eq!(scan_one("rust/src/x.rs", vec_sum).unsuppressed_count(), 0);
        let let_bound = "fn f() { let mut m = HashMap::new(); m.insert(1u32, 2u32); } \
                         // detlint::allow(hash-container, \"fixture\")\n";
        let r = scan_one("rust/src/x.rs", let_bound);
        assert!(!rules_of(&r).contains(&"unordered-float-reduction"));
    }

    // ---- R5 unchecked-cast ----

    #[test]
    fn r5_flags_as_cast_near_accounting_vocab() {
        let src = "fn f(x: f64) -> u64 { let total_bytes = x as u64; total_bytes }\n";
        let r = scan_one("rust/src/x.rs", src);
        assert_eq!(rules_of(&r), ["unchecked-cast"]);
        assert!(r.findings[0].message.contains("total_bytes"));
    }

    #[test]
    fn r5_ignores_non_vocab_float_targets_tests_and_cast_module() {
        assert_eq!(
            scan_one("rust/src/x.rs", "fn f(x: f64) -> u64 { x as u64 }\n").unsuppressed_count(),
            0
        );
        assert_eq!(
            scan_one("rust/src/x.rs", "fn f(n_bytes: u64) -> f64 { n_bytes as f64 }\n")
                .unsuppressed_count(),
            0
        );
        let in_test =
            "#[cfg(test)]\nmod tests {\n fn f(n_bytes: f64) { let x = n_bytes as u64; }\n}\n";
        assert_eq!(scan_one("rust/src/x.rs", in_test).unsuppressed_count(), 0);
        assert_eq!(
            scan_one(rules::CAST_MODULE, "fn f(n_bytes: f64) -> u64 { n_bytes as u64 }\n")
                .unsuppressed_count(),
            0
        );
    }

    #[test]
    fn r5_suppressible_with_reason() {
        let src = "// detlint::allow(unchecked-cast, \"index, bounded by construction\")\n\
                   fn f(n_bytes: u64) -> usize { n_bytes as usize }\n";
        let r = scan_one("rust/src/x.rs", src);
        assert_eq!(r.unsuppressed_count(), 0);
        assert_eq!(r.suppressed_count(), 1);
    }

    // ---- allow-audit ----

    #[test]
    fn audit_flags_unknown_rule_names() {
        let src = "// detlint::allow(no-such-rule, \"why\")\nfn f() {}\n";
        let r = scan_one("rust/src/x.rs", src);
        assert_eq!(rules_of(&r), ["allow-audit"]);
        assert!(r.findings[0].message.contains("unknown rule"));
    }

    #[test]
    fn audit_flags_unused_allows() {
        let src = "// detlint::allow(wall-clock, \"stale\")\nfn f() {}\n";
        let r = scan_one("rust/src/x.rs", src);
        assert_eq!(rules_of(&r), ["allow-audit"]);
        assert!(r.findings[0].message.contains("suppresses nothing"));
    }

    #[test]
    fn audit_flags_empty_reasons_and_malformed_directives() {
        let empty = "fn f() { let m = HashMap::new(); } // detlint::allow(hash-container, \"\")\n";
        let r = scan_one("rust/src/x.rs", empty);
        // The empty-reason allow is discarded, so the R1 finding stays too.
        let audits: Vec<_> =
            r.unsuppressed().filter(|f| f.rule == "allow-audit").collect();
        assert_eq!(audits.len(), 1);
        assert!(audits[0].message.contains("empty reason"));
        assert!(rules_of(&r).contains(&"hash-container"));

        let malformed = "// detlint::allow(hash-container)\nfn f() {}\n";
        let r2 = scan_one("rust/src/x.rs", malformed);
        assert_eq!(rules_of(&r2), ["allow-audit"]);
        assert!(r2.findings[0].message.contains("malformed"));
    }

    // ---- report plumbing ----

    #[test]
    fn json_report_shape() {
        let r = scan_one("rust/src/x.rs", "fn f() { let t = Instant::now(); }\n");
        let j = r.to_json();
        assert_eq!(j.get("version").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("unsuppressed").and_then(Json::as_f64), Some(1.0));
        let findings = j.get("findings").and_then(Json::as_arr).unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(
            findings[0].get("rule").and_then(Json::as_str),
            Some("wall-clock")
        );
        assert!(findings[0].get("suggestion").and_then(Json::as_str).is_some());
        // Round-trips through the in-tree parser.
        assert!(crate::util::json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn human_report_mentions_file_line_and_rule() {
        let r = scan_one("rust/src/x.rs", "fn f() { let t = Instant::now(); }\n");
        let text = r.render_human();
        assert!(text.contains("rust/src/x.rs:1: [wall-clock]"));
        assert!(text.contains("-- 1 unsuppressed, 0 suppressed, 1 files"));
    }

    // ---- the gate itself ----

    #[test]
    fn repo_tree_is_clean() {
        // cargo test runs with the package root as cwd, so `.` is the repo.
        let report = run_tree(Path::new(".")).expect("scan repo tree");
        assert!(report.files > 50, "expected to scan the whole tree, got {}", report.files);
        let residue: Vec<String> = report
            .unsuppressed()
            .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect();
        assert!(residue.is_empty(), "detlint findings:\n{}", residue.join("\n"));
        // The two deliberate suppressions (blockstore + profiler) stay
        // honest: each carries a written reason.
        assert!(report.suppressed_count() >= 2);
        for f in &report.findings {
            if let Some(reason) = &f.suppressed {
                assert!(!reason.trim().is_empty());
            }
        }
    }
}
