//! Minimal Rust lexer for the detlint pass.
//!
//! Deliberately not a full Rust grammar: the rules in
//! [`crate::analysis::rules`] need identifier/number/punct tokens with line
//! numbers, comments (for suppression directives and doc detection),
//! and `#[cfg(test)] mod … { }` region boundaries — nothing more. String
//! and char literals are consumed and *dropped* so rule vocabulary can
//! never match text inside a string; comments are kept on a separate
//! channel. Kept in lockstep with the Python twin used to verify the
//! tree-clean state in toolchain-less containers (see `docs/detlint.md`).

/// Token kind. `Life` is a lifetime tick (`'a`), kept distinct so char
/// literals and lifetimes can't be confused downstream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Punct,
    Life,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub line: u32,
    pub kind: TokKind,
    pub text: String,
}

/// One comment (line or block) with its starting line. `doc` marks
/// `///` / `//!` / `/**` / `/*!` forms; `trailing` marks a comment with
/// code earlier on the same line (a trailing suppression directive applies
/// to its own line, a standalone one to the next code line).
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    pub doc: bool,
    pub trailing: bool,
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Does a raw/byte-raw string literal start at `i` (`r"`, `r#"`,
/// `br##"` …)? Returns the index just past the opening quote and the hash
/// count.
fn raw_string_open(src: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if src.get(j) == Some(&b'b') {
        j += 1;
    }
    if src.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while src.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if src.get(j) == Some(&b'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

/// Lex `src` into (tokens, comments). Never fails: unknown bytes become
/// single-char punct tokens, unterminated literals consume to EOF.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut line_had_code = false;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            line_had_code = false;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // Line comment.
        if b[i..].starts_with(b"//") {
            let j = src[i..].find('\n').map(|k| i + k).unwrap_or(n);
            let text = &src[i..j];
            let doc = text.starts_with("///") || text.starts_with("//!");
            comments.push(Comment { line, text: text.to_string(), doc, trailing: line_had_code });
            i = j;
            continue;
        }
        // Block comment (nested).
        if b[i..].starts_with(b"/*") {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j..].starts_with(b"/*") {
                    depth += 1;
                    j += 2;
                } else if b[j..].starts_with(b"*/") {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            let text = &src[i..j];
            let doc = text.starts_with("/**") || text.starts_with("/*!");
            comments.push(Comment {
                line: start_line,
                text: text.to_string(),
                doc,
                trailing: line_had_code,
            });
            i = j;
            continue;
        }
        // Raw / byte-raw string.
        if let Some((body, hashes)) = raw_string_open(b, i) {
            let mut close = String::with_capacity(1 + hashes);
            close.push('"');
            for _ in 0..hashes {
                close.push('#');
            }
            let j = match src[body..].find(&close) {
                Some(k) => body + k + close.len(),
                None => n,
            };
            line += src[i..j].matches('\n').count() as u32;
            line_had_code = true;
            i = j;
            continue;
        }
        // Plain / byte string.
        if c == b'"' || b[i..].starts_with(b"b\"") {
            let mut j = i + if c == b'"' { 1 } else { 2 };
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    j += 1;
                    break;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                j += 1;
            }
            line_had_code = true;
            i = j;
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            // `'ident` NOT followed by a closing quote is a lifetime.
            if i + 1 < n && is_ident_start(b[i + 1]) {
                let mut j = i + 2;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                if b.get(j) != Some(&b'\'') {
                    toks.push(Tok { line, kind: TokKind::Life, text: src[i..j].to_string() });
                    line_had_code = true;
                    i = j;
                    continue;
                }
            }
            // Char literal: escape form or any single (possibly multi-byte)
            // char up to the closing quote.
            let mut j = i + 1;
            if j < n && b[j] == b'\\' {
                j += 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                j += 1;
            } else {
                j = match src[j..].find('\'') {
                    Some(k) => j + k + 1,
                    None => n,
                };
            }
            line_had_code = true;
            i = j.min(n);
            continue;
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok { line, kind: TokKind::Ident, text: src[i..j].to_string() });
            line_had_code = true;
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n
                && (b[j].is_ascii_alphanumeric() || b[j] == b'_' || b[j] == b'.')
            {
                j += 1;
            }
            let mut text = &src[i..j];
            // Trim trailing range dots: `0..n` lexes as `0`, `.`, `.`, `n`.
            if let Some(k) = text.find("..") {
                text = &text[..k];
            }
            toks.push(Tok { line, kind: TokKind::Num, text: text.to_string() });
            line_had_code = true;
            i += text.len();
            continue;
        }
        toks.push(Tok { line, kind: TokKind::Punct, text: (c as char).to_string() });
        line_had_code = true;
        i += 1;
    }
    (toks, comments)
}

/// Line ranges covered by `#[cfg(test)] mod … { … }` blocks. Rules that
/// guard runtime determinism (R1, R4, R5) skip these; test-only scaffolding
/// may hash and cast freely.
pub fn test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut regions = Vec::new();
    let mut i = 0usize;
    let n = toks.len();
    while i < n {
        let is_cfg_test = toks[i].text == "#"
            && i + 6 < n
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")"
            && toks[i + 6].text == "]";
        if is_cfg_test {
            // `mod` must follow within a few tokens (other attrs allowed).
            let j = i + 7;
            let mut found = None;
            let mut k = j;
            while k < (j + 24).min(n) {
                if toks[k].text == "mod" {
                    found = Some(k);
                    break;
                }
                k += 1;
            }
            if let Some(m) = found {
                let mut bidx = m;
                while bidx < n && toks[bidx].text != "{" {
                    bidx += 1;
                }
                let mut depth = 0usize;
                let mut e = bidx;
                while e < n {
                    if toks[e].text == "{" {
                        depth += 1;
                    } else if toks[e].text == "}" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    e += 1;
                }
                if bidx < n {
                    let end_line =
                        if e < n { toks[e].line } else { toks[n - 1].line };
                    regions.push((toks[bidx].line, end_line));
                }
                i = e + 1;
                continue;
            }
        }
        i += 1;
    }
    regions
}

/// Is `line` inside any of `regions`?
pub fn in_regions(regions: &[(u32, u32)], line: u32) -> bool {
    regions.iter().any(|&(lo, hi)| lo <= line && line <= hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src).0.into_iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_chars_are_dropped() {
        let src = r#"let x = "Instant inside a string"; let c = 'h'; let l: &'a str = y;"#;
        let ids = idents(src);
        assert!(!ids.contains(&"Instant".to_string()), "{ids:?}");
        assert!(ids.contains(&"x".to_string()));
        // The lifetime is a Life token, not an Ident and not a char.
        let (toks, _) = lex(src);
        assert!(toks.iter().any(|t| t.kind == TokKind::Life && t.text == "'a"));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let src = "let a = r#\"HashMap \" inside\"#; let b = \"esc \\\" quote\"; let q = '\\'';";
        assert!(!idents(src).contains(&"HashMap".to_string()));
        assert!(idents(src).contains(&"q".to_string()));
    }

    #[test]
    fn comments_keep_channel_and_trailing_flag() {
        let src = "let x = 1; // detlint::allow(a, \"b\")\n// standalone\nlet y = 2;\n";
        let (_, comments) = lex(src);
        assert_eq!(comments.len(), 2);
        assert!(comments[0].trailing);
        assert!(!comments[1].trailing);
        assert_eq!(comments[1].line, 2);
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let src = "/* outer /* inner */ still */\nlet z = 3;\n";
        let (toks, comments) = lex(src);
        assert_eq!(comments.len(), 1);
        assert!(toks.iter().any(|t| t.text == "z" && t.line == 2));
    }

    #[test]
    fn numbers_stop_at_range_dots() {
        let (toks, _) = lex("for i in 0..n { let h = 0xA272_0001; }");
        let nums: Vec<&str> =
            toks.iter().filter(|t| t.kind == TokKind::Num).map(|t| t.text.as_str()).collect();
        assert_eq!(nums, ["0", "0xA272_0001"]);
    }

    #[test]
    fn test_region_brace_matching() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n fn b() { let x = 1; }\n}\nfn c() {}\n";
        let (toks, _) = lex(src);
        let regions = test_regions(&toks);
        assert_eq!(regions.len(), 1);
        assert!(in_regions(&regions, 4));
        assert!(!in_regions(&regions, 6));
    }
}
