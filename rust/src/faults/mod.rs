//! Deterministic fault-injection and restart engine (ROADMAP: scenario
//! diversity; MegaScale / datacenter-characterization related work).
//!
//! BootSeer's premise is that startup overhead matters *because failures
//! are frequent*: "more than 3.5% of GPU time is wasted due to startup
//! overhead alone". The trace replay historically only played back
//! restarts pre-scripted in the trace; this module *generates* failures on
//! top, as seeded stochastic processes that fire during simulated startup
//! and training hold:
//!
//! * **Hardware crash hazard** — per-job exponential time-to-failure whose
//!   rate scales with the job's GPU count
//!   ([`FaultConfig::hazard_per_gpu_hour`]), the MegaScale-class "bigger
//!   jobs fail more" law. A crash interrupts the in-flight segment at the
//!   failure instant ([`crate::scheduler::SegmentFate::Interrupt`]): the
//!   GPUs return to the pool right there, training since the last resume
//!   point is rolled back ([`FaultConfig::ckpt_interval_s`]), and a retry
//!   re-enters the scheduler queue keeping the chain's priority.
//! * **Warm-vs-cold restart** — whether the retry lands back on its
//!   previous nodes ([`FaultConfig::relocate_prob`]): same nodes keep
//!   their node-local warm state (staged image hot set, unpacked env), a
//!   reschedule evicts it and the restart startup runs cold. The credit
//!   is expressed as artifact residency: the replay hands the restart a
//!   [`crate::artifact::CacheState`] holding the failed attempt's
//!   materialized manifests (via
//!   [`crate::startup::StartupContext::cache`]), and with
//!   `bootseer.delta_resume` also the checkpoint-shard chunks the
//!   rollback did not rewrite — so a warm restart re-fetches strictly
//!   fewer bytes than its cold start.
//! * **Single-node stragglers** — a startup drawn into the straggler fault
//!   ([`FaultConfig::straggler_prob`]) runs its allocation with a badly
//!   degraded node mixed in (the §3.3/§3.4 slow-node phenomenon, injected
//!   rather than background-rate).
//! * **Shared-service brownouts** — Poisson windows
//!   ([`FaultConfig::brownouts_per_week`]) during which the registry /
//!   cluster-cache / HDFS tier serves at a fraction of its capacity
//!   ([`BrownoutWindows`]).
//!
//! Everything is a pure function of `(seed, job id, segment, retry)` via
//! [`fault_seed`] — never of thread interleaving or query order — which is
//! what keeps the parallel cluster replay byte-identical at any
//! `--threads` and lets the replay re-derive per-attempt decisions without
//! threading state through the scheduler. Zero rates
//! ([`FaultConfig::off`]) short-circuit every draw, reproducing the
//! fault-free replay bit-for-bit. Design note: `docs/faults.md`.

use crate::config::defaults as d;
use crate::scheduler::{ChainJob, FaultOracle, SegmentFate};
use crate::util::rng::{mix64, Rng};
use crate::util::salts::{SALT_BROWNOUT, SALT_CRASH, SALT_RELOCATE, SALT_STRAGGLER};
use std::collections::BTreeMap;

/// The seed of the decision stream for `(job, seg, retry)` under `salt`.
/// Pure — the replay and the scheduler oracle derive identical decisions
/// from identical identities, with no shared state.
pub fn fault_seed(seed: u64, job: u64, seg: u64, retry: u64, salt: u64) -> u64 {
    mix64(
        seed ^ salt
            ^ job.wrapping_mul(0x9E3779B97F4A7C15)
            ^ seg.wrapping_mul(0xC2B2AE3D27D4EB4F)
            ^ retry.wrapping_mul(0x165667B19E3779F9),
    )
}

/// Rates and policies of the fault engine. All-zero rates ([`Self::off`])
/// disable every process and reproduce the fault-free replay byte-for-byte.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Hardware crash hazard, failures per GPU-hour. The per-job failure
    /// rate is `hazard * gpus` per hour — large jobs crash proportionally
    /// more often (MegaScale-class fleets see ~2e-5: a 16k-GPU job
    /// interrupted a few times a day).
    pub hazard_per_gpu_hour: f64,
    /// Probability a fault-generated restart is rescheduled onto different
    /// nodes, evicting the node-local warm state (staged image blocks,
    /// unpacked environment). `1 - relocate_prob` restarts land back on
    /// their previous nodes and start warm.
    pub relocate_prob: f64,
    /// Probability a startup's allocation contains a badly degraded node
    /// (injected straggler).
    pub straggler_prob: f64,
    /// Multiplier on the cluster's `straggler_tail_prob` when the
    /// straggler fault fires for a startup.
    pub straggler_severity: f64,
    /// Shared-service brownout arrivals per week (Poisson).
    pub brownouts_per_week: f64,
    /// Duration of one brownout window, seconds.
    pub brownout_duration_s: f64,
    /// Fraction of registry/cache/HDFS capacity still served during a
    /// brownout (0 = total outage, 1 = no effect).
    pub brownout_capacity_factor: f64,
    /// Checkpoint cadence: a crash rolls training back to the last
    /// multiple of this interval; the work since is lost and re-done.
    pub ckpt_interval_s: f64,
    /// Retry cap per scripted segment (termination bound for the
    /// scheduler; the hazard itself makes long retry chains unlikely).
    pub max_retries: u32,
    /// Concurrent-fetch entitlements the registry serves before shedding,
    /// in nodes (cf. `defaults::FLEET_SERVICE_NODES`). `u32::MAX`
    /// disables shedding entirely — the historical behaviour and the
    /// `off`/`paper` default, byte-identical to the pre-shedding replay.
    pub registry_slots: u32,
    /// Concurrent-fetch entitlements of the cluster cache tier before
    /// shedding, in nodes. `u32::MAX` disables.
    pub cache_slots: u32,
    /// Base backoff before a shed fetch retries, seconds (grows
    /// geometrically per attempt with seeded ±50% jitter).
    pub shed_backoff_s: f64,
    /// Shed-retry cap per fetch: the attempt at this index is admitted
    /// unconditionally, so a fetch is never starved — it fetches exactly
    /// once, late.
    pub shed_retries: u32,
    /// Rack scope of a brownout window: the fraction of racks each window
    /// browns out (ToR / rack-level power or network events). `0.0` — the
    /// default and every preset — keeps windows fleet-wide (the historical
    /// behaviour, byte-identical); `(0, 1)` draws a seeded per-window rack
    /// set and only startups with nodes in affected racks slow down;
    /// `1.0` is fleet-wide again. Only meaningful on a multi-rack
    /// topology (`cluster.racks > 1`).
    pub brownout_rack_frac: f64,
}

impl FaultConfig {
    /// No faults: every process disabled. The replay under this config is
    /// byte-identical to the fault-free replay.
    pub fn off() -> FaultConfig {
        FaultConfig {
            hazard_per_gpu_hour: 0.0,
            relocate_prob: 0.0,
            straggler_prob: 0.0,
            straggler_severity: 1.0,
            brownouts_per_week: 0.0,
            brownout_duration_s: 0.0,
            brownout_capacity_factor: 1.0,
            ckpt_interval_s: 1800.0,
            max_retries: 8,
            registry_slots: u32::MAX,
            cache_slots: u32::MAX,
            shed_backoff_s: d::SHED_BACKOFF_S,
            shed_retries: d::SHED_MAX_RETRIES,
            brownout_rack_frac: 0.0,
        }
    }

    /// Production-calibrated defaults: a MegaScale-class crash hazard
    /// (1.8e-5 failures per GPU-hour — a 16k-GPU job interrupted a few
    /// times a day), 30-minute checkpoints, half of the restarts
    /// rescheduled cold, mild straggler injection, and a couple of short
    /// shared-service brownouts per week. Under this config the replayed
    /// week's wasted GPU time lands in the paper's headline band (~3.5%,
    /// "more than 3.5% of GPU time is wasted").
    pub fn paper() -> FaultConfig {
        FaultConfig {
            hazard_per_gpu_hour: 1.8e-5,
            relocate_prob: 0.5,
            straggler_prob: 0.05,
            straggler_severity: 20.0,
            brownouts_per_week: 2.0,
            brownout_duration_s: 1800.0,
            brownout_capacity_factor: 0.35,
            ckpt_interval_s: 1800.0,
            max_retries: 8,
            registry_slots: u32::MAX,
            cache_slots: u32::MAX,
            shed_backoff_s: d::SHED_BACKOFF_S,
            shed_retries: d::SHED_MAX_RETRIES,
            brownout_rack_frac: 0.0,
        }
    }

    /// Restart-storm stress scenario: an order of magnitude more crashes,
    /// most restarts rescheduled cold, long brownouts, and finite
    /// registry/cluster-cache entitlements so the concurrent restart wave
    /// drives real shed/retry traffic. For exercising the scheduler's
    /// interruption path under pressure, not for calibration.
    pub fn storm() -> FaultConfig {
        FaultConfig {
            hazard_per_gpu_hour: 2.0e-4,
            relocate_prob: 0.8,
            straggler_prob: 0.15,
            brownouts_per_week: 10.0,
            brownout_duration_s: 3600.0,
            registry_slots: d::STORM_REGISTRY_SLOTS,
            cache_slots: d::STORM_CACHE_SLOTS,
            ..FaultConfig::paper()
        }
    }

    /// Any process active? `false` guarantees the replay takes the
    /// fault-free paths everywhere.
    pub fn enabled(&self) -> bool {
        self.hazard_per_gpu_hour > 0.0
            || self.straggler_prob > 0.0
            || self.brownouts_per_week > 0.0
    }

    /// Parse a `--faults` rate-spec: a preset name (`off`, `paper`,
    /// `storm`) optionally followed by `key=value` overrides, all
    /// comma-separated. A spec starting with an override applies it over
    /// `paper`. Keys: `hazard`, `relocate`, `straggler`,
    /// `straggler_severity`, `brownouts`, `brownout_s`, `brownout_cap`,
    /// `brownout_racks`, `ckpt_interval`, `max_retries`, `registry_slots`,
    /// `cache_slots`, `shed_backoff`, `shed_retries`. Slot counts must be ≥ 1: a
    /// zero-concurrency service could never admit anything, so it is a
    /// config error, not a silent stall.
    ///
    /// ```
    /// use bootseer::faults::FaultConfig;
    /// assert_eq!(FaultConfig::parse("off").unwrap(), FaultConfig::off());
    /// let c = FaultConfig::parse("paper,hazard=1e-4,relocate=1").unwrap();
    /// assert_eq!(c.hazard_per_gpu_hour, 1e-4);
    /// assert_eq!(c.relocate_prob, 1.0);
    /// ```
    pub fn parse(spec: &str) -> Result<FaultConfig, String> {
        let mut cfg: Option<FaultConfig> = None;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part {
                "off" | "none" => {
                    cfg = Some(FaultConfig::off());
                    continue;
                }
                "paper" | "default" => {
                    cfg = Some(FaultConfig::paper());
                    continue;
                }
                "storm" => {
                    cfg = Some(FaultConfig::storm());
                    continue;
                }
                _ => {}
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("bad --faults part {part:?} (want preset or key=value)"))?;
            let c = cfg.get_or_insert_with(FaultConfig::paper);
            let f: f64 = val
                .trim()
                .parse()
                .map_err(|_| format!("bad --faults value {val:?} for {key:?}"))?;
            match key.trim() {
                "hazard" | "hazard_per_gpu_hour" => c.hazard_per_gpu_hour = f.max(0.0),
                "relocate" | "relocate_prob" => c.relocate_prob = f.clamp(0.0, 1.0),
                "straggler" | "straggler_prob" => c.straggler_prob = f.clamp(0.0, 1.0),
                "straggler_severity" => c.straggler_severity = f.max(1.0),
                "brownouts" | "brownouts_per_week" => c.brownouts_per_week = f.max(0.0),
                "brownout_s" | "brownout_duration_s" => c.brownout_duration_s = f.max(0.0),
                "brownout_cap" | "brownout_capacity_factor" => {
                    c.brownout_capacity_factor = f.clamp(0.0, 1.0)
                }
                "brownout_racks" | "brownout_rack_frac" => {
                    c.brownout_rack_frac = f.clamp(0.0, 1.0)
                }
                "ckpt_interval" | "ckpt_interval_s" => c.ckpt_interval_s = f.max(0.0),
                "max_retries" => c.max_retries = f.max(0.0) as u32,
                "registry_slots" => {
                    if f < 1.0 {
                        return Err(format!(
                            "registry_slots must be >= 1 (got {val:?}); a \
                             zero-concurrency registry can never admit a fetch"
                        ));
                    }
                    c.registry_slots = f as u32;
                }
                "cache_slots" => {
                    if f < 1.0 {
                        return Err(format!(
                            "cache_slots must be >= 1 (got {val:?}); a \
                             zero-concurrency cache can never admit a fetch"
                        ));
                    }
                    c.cache_slots = f as u32;
                }
                "shed_backoff" | "shed_backoff_s" => c.shed_backoff_s = f.max(0.0),
                "shed_retries" => c.shed_retries = f.max(0.0) as u32,
                _ => return Err(format!("unknown --faults key {key:?}")),
            }
        }
        Ok(cfg.unwrap_or_else(FaultConfig::paper))
    }

    /// Read the `[faults]` table of a config document (`faults.preset`
    /// plus per-field overrides; absent table → [`FaultConfig::off`], the
    /// historical behaviour).
    pub fn from_doc(doc: &crate::config::toml::Doc) -> FaultConfig {
        let base = match doc.get("faults.preset").and_then(|v| v.as_str()) {
            Some(p) => FaultConfig::parse(p).unwrap_or_else(|_| FaultConfig::off()),
            None => FaultConfig::off(),
        };
        FaultConfig {
            hazard_per_gpu_hour: doc
                .f64_or("faults.hazard_per_gpu_hour", base.hazard_per_gpu_hour)
                .max(0.0),
            relocate_prob: doc.f64_or("faults.relocate_prob", base.relocate_prob).clamp(0.0, 1.0),
            straggler_prob: doc
                .f64_or("faults.straggler_prob", base.straggler_prob)
                .clamp(0.0, 1.0),
            straggler_severity: doc
                .f64_or("faults.straggler_severity", base.straggler_severity)
                .max(1.0),
            brownouts_per_week: doc
                .f64_or("faults.brownouts_per_week", base.brownouts_per_week)
                .max(0.0),
            brownout_duration_s: doc
                .f64_or("faults.brownout_duration_s", base.brownout_duration_s)
                .max(0.0),
            brownout_capacity_factor: doc
                .f64_or("faults.brownout_capacity_factor", base.brownout_capacity_factor)
                .clamp(0.0, 1.0),
            ckpt_interval_s: doc.f64_or("faults.ckpt_interval_s", base.ckpt_interval_s).max(0.0),
            max_retries: doc.u32_or("faults.max_retries", base.max_retries),
            // Slot counts clamp to ≥ 1 here (a plain struct, no Result);
            // the CLI `parse` path rejects zero loudly.
            registry_slots: doc.u32_or("faults.registry_slots", base.registry_slots).max(1),
            cache_slots: doc.u32_or("faults.cache_slots", base.cache_slots).max(1),
            shed_backoff_s: doc.f64_or("faults.shed_backoff_s", base.shed_backoff_s).max(0.0),
            shed_retries: doc.u32_or("faults.shed_retries", base.shed_retries),
            brownout_rack_frac: doc
                .f64_or("faults.brownout_rack_frac", base.brownout_rack_frac)
                .clamp(0.0, 1.0),
        }
    }

    /// Short human-readable summary of the active processes.
    pub fn describe(&self) -> String {
        if !self.enabled() {
            return "off".to_string();
        }
        let scope = if self.brownout_rack_frac > 0.0 && self.brownout_rack_frac < 1.0 {
            format!(" ({:.0}% of racks)", 100.0 * self.brownout_rack_frac)
        } else {
            String::new()
        };
        format!(
            "hazard {:.1e}/GPU-h, relocate {:.0}%, straggler {:.0}%, {} brownouts/wk{}, ckpt {}s",
            self.hazard_per_gpu_hour,
            100.0 * self.relocate_prob,
            100.0 * self.straggler_prob,
            self.brownouts_per_week,
            scope,
            self.ckpt_interval_s
        )
    }
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig::off()
    }
}

/// The seeded hazard oracle the cluster replay plugs into
/// [`crate::scheduler::schedule_chains_with`]. Holds the per-chain startup
/// estimates so a mid-hold failure can tell "failed during startup"
/// (nothing trained, nothing lost) from "failed during training" (work
/// since the last checkpoint rolled back).
pub struct FaultEngine {
    cfg: FaultConfig,
    seed: u64,
    est_by_id: BTreeMap<u64, f64>,
}

impl FaultEngine {
    /// Build the oracle: `ests` maps chain id → estimated startup seconds
    /// (the non-training prefix of every segment hold).
    pub fn new(cfg: FaultConfig, seed: u64, ests: &[(u64, f64)]) -> FaultEngine {
        FaultEngine { cfg, seed, est_by_id: ests.iter().copied().collect() }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Does the fault-generated restart of `(job, seg, retry)` land on
    /// different nodes than the failed run (cold node-local caches)?
    /// `retry` is the retry number of the *restart* (≥ 1).
    pub fn relocated(&self, job: u64, seg: u64, retry: u32) -> bool {
        if self.cfg.relocate_prob <= 0.0 {
            return false;
        }
        if self.cfg.relocate_prob >= 1.0 {
            return true;
        }
        let mut rng = Rng::seeded(fault_seed(self.seed, job, seg, retry as u64, SALT_RELOCATE));
        rng.chance(self.cfg.relocate_prob)
    }

    /// Does the startup `(job, attempt)` draw an injected straggler node?
    pub fn straggler(&self, job: u64, attempt: u32) -> bool {
        if self.cfg.straggler_prob <= 0.0 {
            return false;
        }
        let mut rng = Rng::seeded(fault_seed(self.seed, job, attempt as u64, 0, SALT_STRAGGLER));
        rng.chance(self.cfg.straggler_prob)
    }
}

impl FaultOracle for FaultEngine {
    fn fate(
        &self,
        chain: &ChainJob,
        seg: usize,
        retry: u32,
        _start_s: f64,
        hold_s: f64,
    ) -> SegmentFate {
        if self.cfg.hazard_per_gpu_hour <= 0.0 || retry >= self.cfg.max_retries {
            return SegmentFate::Complete;
        }
        let lambda = self.cfg.hazard_per_gpu_hour * chain.gpus as f64 / 3600.0;
        if lambda <= 0.0 {
            return SegmentFate::Complete;
        }
        let mut rng =
            Rng::seeded(fault_seed(self.seed, chain.id, seg as u64, retry as u64, SALT_CRASH));
        let ttf = rng.exponential(lambda);
        if ttf >= hold_s {
            return SegmentFate::Complete;
        }
        let est = self.est_by_id.get(&chain.id).copied().unwrap_or(0.0).min(hold_s);
        // Failed during startup → nothing trained; during training → roll
        // back to the last checkpoint, losing the remainder.
        let trained = (ttf - est).max(0.0);
        let lost = if self.cfg.ckpt_interval_s > 0.0 {
            trained % self.cfg.ckpt_interval_s
        } else {
            trained
        };
        let retained = trained - lost;
        SegmentFate::Interrupt {
            after_s: ttf,
            lost_train_s: lost,
            // The retry re-runs a full startup plus the not-yet-retained
            // training (including re-doing the rolled-back work).
            retry_hold_s: (hold_s - retained).max(est),
        }
    }
}

/// Shared-service brownout windows over the replay horizon: Poisson
/// arrivals, fixed duration, generated once from the seed (never from
/// per-unit state) so the parallel replay sees one consistent outage
/// calendar.
#[derive(Clone, Debug)]
pub struct BrownoutWindows {
    windows: Vec<(f64, f64)>,
    capacity_factor: f64,
    /// Fraction of racks each window affects (`FaultConfig::
    /// brownout_rack_frac`); 0 or 1 → fleet-wide.
    rack_frac: f64,
    /// Seed the per-window rack memberships are derived from (pure, no
    /// stored sets — the parallel replay re-derives identical memberships
    /// from any thread).
    seed: u64,
}

impl BrownoutWindows {
    pub fn generate(cfg: &FaultConfig, seed: u64, horizon_s: f64) -> BrownoutWindows {
        let mut windows = Vec::new();
        if cfg.brownouts_per_week > 0.0 && cfg.brownout_duration_s > 0.0 && horizon_s > 0.0 {
            let rate = cfg.brownouts_per_week / (7.0 * 86400.0);
            let mut rng = Rng::seeded(mix64(seed ^ SALT_BROWNOUT));
            let mut t = rng.exponential(rate);
            while t < horizon_s {
                windows.push((t, t + cfg.brownout_duration_s));
                t += cfg.brownout_duration_s + rng.exponential(rate);
            }
        }
        BrownoutWindows {
            windows,
            capacity_factor: cfg.brownout_capacity_factor,
            rack_frac: cfg.brownout_rack_frac,
            seed,
        }
    }

    /// Are windows rack-scoped (a strict subset of racks per window)?
    /// `false` → every window is fleet-wide and
    /// [`Self::capacity_scale_racks`] degenerates to
    /// [`Self::capacity_scale`].
    pub fn scoped(&self) -> bool {
        self.rack_frac > 0.0 && self.rack_frac < 1.0
    }

    /// Does window `k` brown out rack `rack`? Pure in `(seed, k, rack)` —
    /// a seeded Bernoulli draw at `rack_frac`; fleet-wide configurations
    /// affect every rack.
    pub fn window_affects_rack(&self, k: usize, rack: u32) -> bool {
        if !self.scoped() {
            return true;
        }
        let mut rng = Rng::seeded(mix64(
            self.seed
                ^ SALT_BROWNOUT
                ^ (k as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (rack as u64 + 1).wrapping_mul(0xC2B2AE3D27D4EB4F),
        ));
        rng.chance(self.rack_frac)
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }

    pub fn windows(&self) -> &[(f64, f64)] {
        &self.windows
    }

    /// Fraction of `[a, b]` covered by brownout windows.
    pub fn overlap_fraction(&self, a: f64, b: f64) -> f64 {
        if b <= a || self.windows.is_empty() {
            return 0.0;
        }
        let mut covered = 0.0;
        for &(w0, w1) in &self.windows {
            covered += (b.min(w1) - a.max(w0)).max(0.0);
        }
        (covered / (b - a)).min(1.0)
    }

    /// Capacity multiplier for a startup occupying `[a, b]`: 1.0 outside
    /// brownouts, down to `capacity_factor` when fully inside one.
    pub fn capacity_scale(&self, a: f64, b: f64) -> f64 {
        let f = self.overlap_fraction(a, b);
        1.0 - f * (1.0 - self.capacity_factor)
    }

    /// [`Self::capacity_scale`] for a startup whose allocation spans
    /// `racks` (deduplicated rack ids): each overlapping window is
    /// weighted by the fraction of the startup's racks it browns out, so
    /// a ToR-scoped event that misses the allocation entirely costs
    /// nothing and one that covers every rack costs exactly the fleet-wide
    /// amount. Un-scoped windows or an empty rack list reproduce
    /// [`Self::capacity_scale`] bit-for-bit.
    pub fn capacity_scale_racks(&self, a: f64, b: f64, racks: &[u32]) -> f64 {
        if !self.scoped() || racks.is_empty() {
            return self.capacity_scale(a, b);
        }
        if b <= a || self.windows.is_empty() {
            return 1.0;
        }
        let mut covered = 0.0;
        for (k, &(w0, w1)) in self.windows.iter().enumerate() {
            let ov = (b.min(w1) - a.max(w0)).max(0.0);
            if ov <= 0.0 {
                continue;
            }
            let hit = racks.iter().filter(|&&r| self.window_affects_rack(k, r)).count();
            covered += ov * hit as f64 / racks.len() as f64;
        }
        let f = (covered / (b - a)).min(1.0);
        1.0 - f * (1.0 - self.capacity_factor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(id: u64, gpus: u32) -> ChainJob {
        ChainJob { id, submit_s: 0.0, gpus, priority: 1, segments: vec![1000.0] }
    }

    #[test]
    fn off_never_fires() {
        let eng = FaultEngine::new(FaultConfig::off(), 7, &[(1, 100.0)]);
        let c = chain(1, 2048);
        for seg in 0..4usize {
            assert_eq!(eng.fate(&c, seg, 0, 0.0, 1e9), SegmentFate::Complete);
        }
        assert!(!eng.relocated(1, 0, 1));
        assert!(!eng.straggler(1, 0));
        assert!(!FaultConfig::off().enabled());
        assert!(FaultConfig::paper().enabled());
    }

    #[test]
    fn fate_is_deterministic_and_identity_keyed() {
        let eng = FaultEngine::new(FaultConfig::storm(), 7, &[(1, 100.0), (2, 100.0)]);
        let c = chain(1, 2048);
        let a = eng.fate(&c, 0, 0, 0.0, 1e6);
        let b = eng.fate(&c, 0, 0, 500.0, 1e6); // start time must not matter
        assert_eq!(a, b);
        // Different retry → independent draw.
        let r1 = eng.fate(&c, 0, 1, 0.0, 1e6);
        assert!(a != r1 || matches!(a, SegmentFate::Complete));
        // A different engine seed changes the outcome stream.
        let eng2 = FaultEngine::new(FaultConfig::storm(), 8, &[(1, 100.0)]);
        let a2 = eng2.fate(&c, 0, 0, 0.0, 1e6);
        assert!(a != a2 || matches!(a, SegmentFate::Complete));
    }

    #[test]
    fn big_jobs_fail_sooner_on_average() {
        let eng = FaultEngine::new(FaultConfig::paper(), 3, &[]);
        let hold = 1e7;
        let mean_ttf = |gpus: u32| {
            let mut sum = 0.0;
            let mut n = 0;
            for id in 1..400u64 {
                if let SegmentFate::Interrupt { after_s, .. } =
                    eng.fate(&chain(id, gpus), 0, 0, 0.0, hold)
                {
                    sum += after_s;
                    n += 1;
                }
            }
            sum / n.max(1) as f64
        };
        let small = mean_ttf(64);
        let large = mean_ttf(2048);
        assert!(large < small / 4.0, "2048-GPU TTF {large} vs 64-GPU {small}");
    }

    #[test]
    fn rollback_respects_checkpoint_interval() {
        // λ = 1.4e-3 × 512 / 3600 → mean TTF ≈ 5,000 s: most failures land
        // inside the training window (est=300 .. hold=50,000).
        let cfg = FaultConfig { hazard_per_gpu_hour: 1.4e-3, ..FaultConfig::paper() };
        let est = 300.0;
        let eng = FaultEngine::new(cfg.clone(), 5, &[(1, est)]);
        let mut saw_training_failure = false;
        for seg in 0..50usize {
            match eng.fate(&chain(1, 512), seg, 0, 0.0, 50_000.0) {
                SegmentFate::Complete => {}
                SegmentFate::Interrupt { after_s, lost_train_s, retry_hold_s } => {
                    assert!(lost_train_s <= cfg.ckpt_interval_s + 1e-9);
                    assert!(lost_train_s >= 0.0);
                    assert!(retry_hold_s >= est - 1e-9, "retry re-runs a startup");
                    assert!(retry_hold_s <= 50_000.0 + 1e-9);
                    if after_s < est {
                        assert_eq!(lost_train_s, 0.0, "startup failure trains nothing");
                        assert!((retry_hold_s - 50_000.0).abs() < 1e-6);
                    } else {
                        saw_training_failure = true;
                        let retained = (after_s - est) - lost_train_s;
                        assert!((retry_hold_s - (50_000.0 - retained)).abs() < 1e-6);
                    }
                }
            }
        }
        assert!(saw_training_failure);
    }

    #[test]
    fn retry_cap_terminates() {
        let cfg = FaultConfig { hazard_per_gpu_hour: 10.0, max_retries: 3, ..FaultConfig::off() };
        let eng = FaultEngine::new(cfg, 1, &[(1, 10.0)]);
        let c = chain(1, 8192);
        assert!(matches!(eng.fate(&c, 0, 0, 0.0, 1e6), SegmentFate::Interrupt { .. }));
        assert_eq!(eng.fate(&c, 0, 3, 0.0, 1e6), SegmentFate::Complete);
    }

    #[test]
    fn relocation_and_straggler_rates() {
        let cfg = FaultConfig { relocate_prob: 0.3, straggler_prob: 0.1, ..FaultConfig::paper() };
        let eng = FaultEngine::new(cfg, 11, &[]);
        let reloc =
            (1..4000u64).filter(|&j| eng.relocated(j, 0, 1)).count() as f64 / 4000.0;
        let strag = (1..4000u64).filter(|&j| eng.straggler(j, 0)).count() as f64 / 4000.0;
        assert!((reloc - 0.3).abs() < 0.05, "relocation rate {reloc}");
        assert!((strag - 0.1).abs() < 0.03, "straggler rate {strag}");
        // Edge probabilities are exact.
        let all = FaultEngine::new(
            FaultConfig { relocate_prob: 1.0, ..FaultConfig::paper() },
            11,
            &[],
        );
        assert!(all.relocated(1, 0, 1));
    }

    #[test]
    fn brownout_windows_deterministic_and_bounded() {
        let cfg = FaultConfig::storm();
        let a = BrownoutWindows::generate(&cfg, 9, 7.0 * 86400.0);
        let b = BrownoutWindows::generate(&cfg, 9, 7.0 * 86400.0);
        assert_eq!(a.windows(), b.windows());
        assert!(!a.is_empty(), "storm preset should produce windows in a week");
        for &(w0, w1) in a.windows() {
            assert!(w1 - w0 == cfg.brownout_duration_s);
            assert!(w0 >= 0.0 && w0 < 7.0 * 86400.0);
        }
        // Non-overlapping by construction.
        for w in a.windows().windows(2) {
            assert!(w[0].1 <= w[1].0 + 1e-9);
        }
        let none = BrownoutWindows::generate(&FaultConfig::off(), 9, 7.0 * 86400.0);
        assert!(none.is_empty());
        assert_eq!(none.capacity_scale(0.0, 1000.0), 1.0);
    }

    #[test]
    fn brownout_overlap_math() {
        let w = BrownoutWindows {
            windows: vec![(100.0, 200.0), (400.0, 500.0)],
            capacity_factor: 0.25,
            rack_frac: 0.0,
            seed: 0,
        };
        assert_eq!(w.overlap_fraction(0.0, 100.0), 0.0);
        assert_eq!(w.overlap_fraction(100.0, 200.0), 1.0);
        assert!((w.overlap_fraction(150.0, 450.0) - (50.0 + 50.0) / 300.0).abs() < 1e-12);
        assert_eq!(w.capacity_scale(100.0, 200.0), 0.25);
        assert_eq!(w.capacity_scale(0.0, 50.0), 1.0);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(FaultConfig::parse("off").unwrap(), FaultConfig::off());
        assert_eq!(FaultConfig::parse("paper").unwrap(), FaultConfig::paper());
        assert_eq!(FaultConfig::parse("storm").unwrap(), FaultConfig::storm());
        let c = FaultConfig::parse("storm,hazard=1e-3,max_retries=2").unwrap();
        assert_eq!(c.hazard_per_gpu_hour, 1e-3);
        assert_eq!(c.max_retries, 2);
        assert_eq!(c.relocate_prob, FaultConfig::storm().relocate_prob);
        // Bare overrides start from the paper preset.
        let c = FaultConfig::parse("hazard=0").unwrap();
        assert_eq!(c.hazard_per_gpu_hour, 0.0);
        assert_eq!(c.ckpt_interval_s, FaultConfig::paper().ckpt_interval_s);
        assert!(FaultConfig::parse("bogus").is_err());
        assert!(FaultConfig::parse("hazard=abc").is_err());
        assert!(FaultConfig::parse("nope=1").is_err());
    }

    #[test]
    fn from_doc_overrides() {
        let doc = crate::config::toml::Doc::parse(
            r#"
            [faults]
            preset = "paper"
            hazard_per_gpu_hour = 5e-5
            relocate_prob = 0.9
            "#,
        )
        .unwrap();
        let c = FaultConfig::from_doc(&doc);
        assert_eq!(c.hazard_per_gpu_hour, 5e-5);
        assert_eq!(c.relocate_prob, 0.9);
        assert_eq!(c.ckpt_interval_s, FaultConfig::paper().ckpt_interval_s);
        // Absent table → off.
        let empty = crate::config::toml::Doc::parse("").unwrap();
        assert_eq!(FaultConfig::from_doc(&empty), FaultConfig::off());
    }

    #[test]
    fn shed_config_parses_and_rejects_zero_slots() {
        let c = FaultConfig::parse("storm").unwrap();
        assert_eq!(c.registry_slots, d::STORM_REGISTRY_SLOTS);
        assert_eq!(c.cache_slots, d::STORM_CACHE_SLOTS);
        let c =
            FaultConfig::parse("paper,registry_slots=32,shed_backoff=2.5,shed_retries=5").unwrap();
        assert_eq!(c.registry_slots, 32);
        assert_eq!(c.cache_slots, u32::MAX);
        assert_eq!(c.shed_backoff_s, 2.5);
        assert_eq!(c.shed_retries, 5);
        // A zero-concurrency limit can never admit anything: config error.
        assert!(FaultConfig::parse("registry_slots=0").is_err());
        assert!(FaultConfig::parse("cache_slots=0").is_err());
        // off/paper keep shedding disabled entirely (the historical path).
        assert_eq!(FaultConfig::off().registry_slots, u32::MAX);
        assert_eq!(FaultConfig::off().cache_slots, u32::MAX);
        assert_eq!(FaultConfig::paper().registry_slots, u32::MAX);
        assert_eq!(FaultConfig::paper().cache_slots, u32::MAX);
        // The doc path (no Result) clamps instead of erroring.
        let doc = crate::config::toml::Doc::parse("[faults]\ncache_slots = 0\n").unwrap();
        assert_eq!(FaultConfig::from_doc(&doc).cache_slots, 1);
    }

    #[test]
    fn rack_scoped_brownouts_weight_by_affected_racks() {
        let mk = |frac: f64| BrownoutWindows {
            windows: vec![(100.0, 200.0)],
            capacity_factor: 0.25,
            rack_frac: frac,
            seed: 42,
        };
        // Un-scoped (0 or 1) degenerates to the fleet-wide math for any
        // rack set, bit-for-bit.
        for frac in [0.0, 1.0] {
            let w = mk(frac);
            assert!(!w.scoped());
            assert_eq!(
                w.capacity_scale_racks(100.0, 200.0, &[0, 1, 2]).to_bits(),
                w.capacity_scale(100.0, 200.0).to_bits()
            );
            assert!(w.window_affects_rack(0, 7));
        }
        let w = mk(0.5);
        assert!(w.scoped());
        // Membership is a pure function of (seed, window, rack).
        let hits: Vec<bool> = (0..64).map(|r| w.window_affects_rack(0, r)).collect();
        assert_eq!(hits, (0..64).map(|r| w.window_affects_rack(0, r)).collect::<Vec<_>>());
        let affected: Vec<u32> =
            (0..64).filter(|&r| w.window_affects_rack(0, r)).collect();
        let missed: Vec<u32> =
            (0..64).filter(|&r| !w.window_affects_rack(0, r)).collect();
        assert!(!affected.is_empty() && !missed.is_empty(), "0.5 splits 64 racks");
        // Fully-inside window: all-affected racks pay the full factor,
        // all-missed racks pay nothing, a 50/50 mix pays half the slowdown.
        assert_eq!(w.capacity_scale_racks(100.0, 200.0, &affected[..2]), 0.25);
        assert_eq!(w.capacity_scale_racks(100.0, 200.0, &missed[..2]), 1.0);
        let half = w.capacity_scale_racks(100.0, 200.0, &[affected[0], missed[0]]);
        assert!((half - (1.0 - 0.5 * 0.75)).abs() < 1e-12, "half-affected {half}");
        // Outside every window nothing changes.
        assert_eq!(w.capacity_scale_racks(0.0, 50.0, &affected), 1.0);
    }

    #[test]
    fn rack_frac_parses_and_defaults_off() {
        assert_eq!(FaultConfig::off().brownout_rack_frac, 0.0);
        assert_eq!(FaultConfig::paper().brownout_rack_frac, 0.0);
        assert_eq!(FaultConfig::storm().brownout_rack_frac, 0.0);
        let c = FaultConfig::parse("storm,brownout_racks=0.25").unwrap();
        assert_eq!(c.brownout_rack_frac, 0.25);
        let c = FaultConfig::parse("brownout_rack_frac=2").unwrap();
        assert_eq!(c.brownout_rack_frac, 1.0);
        let doc = crate::config::toml::Doc::parse(
            "[faults]\npreset = \"storm\"\nbrownout_rack_frac = 0.5\n",
        )
        .unwrap();
        assert_eq!(FaultConfig::from_doc(&doc).brownout_rack_frac, 0.5);
        let w = BrownoutWindows::generate(&c, 9, 7.0 * 86400.0);
        // paper + rack_frac=1.0 clamps to fleet-wide (not scoped).
        assert!(!w.scoped());
        let d = FaultConfig::parse("storm,brownout_racks=0.25").unwrap().describe();
        assert!(d.contains("25% of racks"), "{d}");
    }

    #[test]
    fn describe_mentions_processes() {
        assert_eq!(FaultConfig::off().describe(), "off");
        let d = FaultConfig::paper().describe();
        assert!(d.contains("hazard") && d.contains("brownouts"));
    }
}
