//! # BootSeer — startup-bottleneck analysis & mitigation for LLM training
//!
//! Reproduction of *"BootSeer: Analyzing and Mitigating Initialization
//! Bottlenecks in Large-Scale LLM Training"* (ByteDance Seed, 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system: a cluster startup pipeline
//!   with BootSeer's three optimizations (hot-block record-and-prefetch
//!   image loading, job-level environment caching, striped HDFS-FUSE
//!   checkpoint resumption), a stage profiler, and the discrete-event
//!   cluster substrate everything is evaluated on.
//! * **L2/L1 (python/, build-time only)** — the MoE training workload
//!   (JAX fwd/bwd + Pallas expert kernel) AOT-lowered to HLO text.
//! * **runtime** — loads the HLO artifacts over PJRT and runs real training
//!   steps after startup completes.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results on every figure.

pub mod ckpt;
pub mod config;
pub mod env;
pub mod figures;
pub mod hdfs;
pub mod image;
pub mod profiler;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod startup;
pub mod trace;
pub mod trainer;
pub mod util;

/// Crate version (from Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
