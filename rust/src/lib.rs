//! # BootSeer — startup-bottleneck analysis & mitigation for LLM training
//!
//! Reproduction of *"BootSeer: Analyzing and Mitigating Initialization
//! Bottlenecks in Large-Scale LLM Training"* (ByteDance Seed, 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system: a cluster startup pipeline
//!   with BootSeer's three optimizations (hot-block record-and-prefetch
//!   image loading, job-level environment caching, striped HDFS-FUSE
//!   checkpoint resumption), a stage profiler, and the discrete-event
//!   cluster substrate everything is evaluated on.
//! * **L2/L1 (python/, build-time only)** — the MoE training workload
//!   (JAX fwd/bwd + Pallas expert kernel) AOT-lowered to HLO text.
//! * **runtime** (feature `pjrt`) — loads the HLO artifacts over PJRT and
//!   runs real training steps after startup completes. Gated because the
//!   `xla` crate is not in the offline crate set; the default build is
//!   dependency-free.
//!
//! All three mitigations move bytes through one content-addressed
//! [`artifact`] layer (manifests, per-node cache state, a tiered transfer
//! planner) — see `docs/artifact_layer.md`.
//!
//! The cluster-scale evaluation path is [`trace`]: a synthetic production
//! week scheduled over a finite GPU pool by [`scheduler`], then replayed
//! startup-by-startup (in parallel, contention-aware) through [`startup`].
//! See `README.md` for the module map and `docs/replay.md` for the replay
//! engine's design. On top of replay, [`trace::batch_replay`] evaluates
//! many what-if configurations against one shared replay prefix, and
//! [`optimize`] closes the loop with a seeded successive-halving search
//! over the mitigation knob space (`docs/optimize.md`).

pub mod analysis;
pub mod artifact;
pub mod ckpt;
pub mod config;
pub mod env;
pub mod faults;
pub mod figures;
pub mod hdfs;
pub mod image;
pub mod optimize;
pub mod profiler;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod startup;
pub mod trace;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod util;

/// Crate version (from Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
