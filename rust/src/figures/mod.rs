//! Figure generators: one function per data figure in the paper, each
//! producing the measured series (plus a rendered table and JSON export).
//! Benches and the CLI are thin wrappers over these.

use crate::config::defaults as d;
use crate::config::{BootseerConfig, CachePolicy, ClusterConfig, JobConfig, OverlapMode};
use crate::faults::FaultConfig;
use crate::profiler::Stage;
use crate::startup::{
    run_startup, run_startup_with, StartupContext, StartupKind, StartupOutcome, World,
};
use crate::trace::{
    bucket_of, gen_trace, replay, replay_cluster, ReplayOptions, ReplayResult, SCALE_BUCKETS,
};
use crate::util::human;
use crate::util::json::Json;
use crate::util::stats::{self, BoxSummary, Histogram};
use std::sync::Arc;

/// Jobs in the default synthetic week (the paper's week saw 28k; we default
/// lower and scale — override with BOOTSEER_TRACE_JOBS).
pub fn default_trace_jobs() -> usize {
    std::env::var("BOOTSEER_TRACE_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1") {
            120
        } else {
            400
        })
}

/// Run (or reuse) the week replay all §3 figures share: the full two-phase
/// cluster replay (scheduler-derived queue waits over a demand-sized pool,
/// contention-aware parallel startup simulation — see `trace::replay`).
pub fn week_replay(seed: u64) -> ReplayResult {
    let trace = gen_trace(seed, default_trace_jobs(), 7.0 * 86400.0);
    replay(&trace, &ClusterConfig::default(), &BootseerConfig::baseline(), seed)
}

/// Fleet-year replay: the same two-phase pipeline over a 365-day horizon.
/// `epochs` is the replay-timeline shard count (0 auto-shards one epoch per
/// simulated day) — a pure performance knob, byte-identical at any value.
pub fn fleet_replay(seed: u64, jobs: usize, threads: usize, epochs: usize) -> ReplayResult {
    let trace = gen_trace(seed, jobs, 365.0 * 86400.0);
    let opts = ReplayOptions::new().with_threads(threads).with_epochs(epochs);
    replay_cluster(&trace, &ClusterConfig::default(), &BootseerConfig::baseline(), seed, &opts)
}

// ---------------------------------------------------------------- Fig 1 --

pub struct Fig01 {
    pub train_gpu_hours: f64,
    pub startup_gpu_hours: f64,
}

impl Fig01 {
    pub fn fraction(&self) -> f64 {
        self.startup_gpu_hours / (self.startup_gpu_hours + self.train_gpu_hours)
    }

    pub fn render(&self) -> String {
        format!(
            "cluster day: training {:.0} GPU-h, startup {:.0} GPU-h → startup fraction {:.2}%\n\
             paper: \"more than 3.5% of GPU time is wasted due to startup overhead\"\n",
            self.train_gpu_hours,
            self.startup_gpu_hours,
            100.0 * self.fraction()
        )
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("train_gpu_hours", self.train_gpu_hours)
            .set("startup_gpu_hours", self.startup_gpu_hours)
            .set("startup_fraction", self.fraction());
        j
    }
}

pub fn fig01(r: &ReplayResult) -> Fig01 {
    Fig01 { train_gpu_hours: r.train_gpu_hours, startup_gpu_hours: r.startup_gpu_hours }
}

// ------------------------------------------------------------- Fig 3a/3b --

pub struct Fig03 {
    /// Per bucket: (label, job-level box, node-level box).
    pub rows: Vec<(String, Option<BoxSummary>, Option<BoxSummary>)>,
}

pub fn fig03(r: &ReplayResult) -> Fig03 {
    let mut job_level: Vec<Vec<f64>> = vec![Vec::new(); SCALE_BUCKETS.len()];
    let mut node_level: Vec<Vec<f64>> = vec![Vec::new(); SCALE_BUCKETS.len()];
    for jr in &r.jobs {
        let b = bucket_of(jr.job.gpus);
        for attempt in r.svc.db.attempts(jr.job.id) {
            // Job-level overhead = submission → training begin = end of the
            // ModelInit span for this attempt.
            if let Some((_, end)) =
                r.svc.db.attempt_stage_span(jr.job.id, attempt, Stage::ModelInit)
            {
                job_level[b].push(end);
            }
            for node in r.svc.db.job_nodes(jr.job.id) {
                if let Some(x) = r.svc.db.node_startup_overhead(jr.job.id, attempt, node) {
                    node_level[b].push(x);
                }
            }
        }
    }
    Fig03 {
        rows: SCALE_BUCKETS
            .iter()
            .enumerate()
            .map(|(i, &(_, _, label))| {
                (
                    label.to_string(),
                    (!job_level[i].is_empty()).then(|| BoxSummary::of(&job_level[i])),
                    (!node_level[i].is_empty()).then(|| BoxSummary::of(&node_level[i])),
                )
            })
            .collect(),
    }
}

impl Fig03 {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "scale".to_string(),
            "job-level med".to_string(),
            "job q1..q3".to_string(),
            "node-level med".to_string(),
            "node q1..q3".to_string(),
        ]];
        for (label, j, n) in &self.rows {
            let fmt = |b: &Option<BoxSummary>| match b {
                Some(b) => (
                    human::secs(b.median),
                    format!("{}..{}", human::secs(b.q1), human::secs(b.q3)),
                ),
                None => ("-".to_string(), "-".to_string()),
            };
            let (jm, jq) = fmt(j);
            let (nm, nq) = fmt(n);
            rows.push(vec![label.clone(), jm, jq, nm, nq]);
        }
        format!(
            "{}paper: >100-GPU jobs take ~6-7 min job-level; node-level ≈1 min lower\n",
            human::table(&rows)
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .rows
            .iter()
            .map(|(label, j, n)| {
                let mut o = Json::obj();
                o.set("bucket", label.as_str());
                if let Some(b) = j {
                    o.set("job_median", b.median).set("job_q1", b.q1).set("job_q3", b.q3);
                }
                if let Some(b) = n {
                    o.set("node_median", b.median).set("node_q1", b.q1).set("node_q3", b.q3);
                }
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("buckets", Json::Arr(arr));
        j
    }
}

// --------------------------------------------------------------- Fig 4 --

pub struct Fig04 {
    pub rows: Vec<(String, Option<BoxSummary>, usize)>,
}

pub fn fig04(r: &ReplayResult) -> Fig04 {
    let mut per_bucket: Vec<Vec<f64>> = vec![Vec::new(); SCALE_BUCKETS.len()];
    let mut counts = vec![0usize; SCALE_BUCKETS.len()];
    for jr in &r.jobs {
        let b = bucket_of(jr.job.gpus);
        per_bucket[b].push((jr.job.full_startups + jr.job.hot_updates) as f64);
        counts[b] += 1;
    }
    Fig04 {
        rows: SCALE_BUCKETS
            .iter()
            .enumerate()
            .map(|(i, &(_, _, label))| {
                (
                    label.to_string(),
                    (!per_bucket[i].is_empty()).then(|| BoxSummary::of(&per_bucket[i])),
                    counts[i],
                )
            })
            .collect(),
    }
}

impl Fig04 {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "scale".to_string(),
            "startups med".to_string(),
            "q1..q3".to_string(),
            "max".to_string(),
            "#jobs".to_string(),
        ]];
        for (label, b, n) in &self.rows {
            match b {
                Some(b) => rows.push(vec![
                    label.clone(),
                    format!("{:.0}", b.median),
                    format!("{:.0}..{:.0}", b.q1, b.q3),
                    format!("{:.0}", b.max),
                    n.to_string(),
                ]),
                None => rows.push(vec![
                    label.clone(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    n.to_string(),
                ]),
            }
        }
        format!(
            "{}paper: <100-GPU jobs ≈1 startup; larger jobs 2-8, worst cases 20+\n",
            human::table(&rows)
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .rows
            .iter()
            .map(|(label, b, n)| {
                let mut o = Json::obj();
                o.set("bucket", label.as_str()).set("n_jobs", *n);
                if let Some(b) = b {
                    o.set("median", b.median).set("q3", b.q3).set("max", b.max);
                }
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("buckets", Json::Arr(arr));
        j
    }
}

// --------------------------------------------------------------- Fig 5 --

pub struct Fig05 {
    pub rows: Vec<(Stage, BoxSummary)>,
}

pub fn fig05(r: &ReplayResult) -> Fig05 {
    let mut rows = Vec::new();
    // Pre-worker stages: job-level spans.
    for stage in [Stage::Queuing, Stage::Allocation] {
        let durs: Vec<f64> = r
            .svc
            .db
            .rows
            .iter()
            .filter(|row| row.stage == stage)
            .map(|row| row.duration())
            .collect();
        if !durs.is_empty() {
            rows.push((stage, BoxSummary::of(&durs)));
        }
    }
    for stage in Stage::WORKER_PHASE {
        let durs = r.svc.db.node_durations(stage);
        if !durs.is_empty() {
            rows.push((stage, BoxSummary::of(&durs)));
        }
    }
    Fig05 { rows }
}

impl Fig05 {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "stage".to_string(),
            "median".to_string(),
            "q1..q3".to_string(),
            "whisker hi".to_string(),
        ]];
        for (stage, b) in &self.rows {
            rows.push(vec![
                stage.name().to_string(),
                human::secs(b.median),
                format!("{}..{}", human::secs(b.q1), human::secs(b.q3)),
                human::secs(b.whisker_hi),
            ]);
        }
        format!(
            "{}paper bands: queuing ~100s; alloc ~s; image 20-40s; env 100-300s; model-init 100-200s\n",
            human::table(&rows)
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .rows
            .iter()
            .map(|(s, b)| {
                let mut o = Json::obj();
                o.set("stage", s.name()).set("median", b.median).set("q1", b.q1).set("q3", b.q3);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("stages", Json::Arr(arr));
        j
    }
}

// --------------------------------------------------------------- Fig 6 --

pub struct Fig06 {
    /// (gpus, Max/Median samples across repeated jobs).
    pub rows: Vec<(u32, BoxSummary)>,
}

/// Dedicated scale sweep: install-script Max/Median ratio vs job scale.
pub fn fig06(seeds: u32) -> Fig06 {
    let scales = [16u32, 64, 256, 1024, 4096, 11520];
    let cluster = ClusterConfig::default();
    let rows = scales
        .iter()
        .map(|&gpus| {
            let job = JobConfig::paper_moe(gpus);
            let ratios: Vec<f64> = (0..seeds)
                .map(|s| {
                    let mut w = World::new();
                    let o = run_startup(
                        gpus as u64,
                        s,
                        &cluster,
                        &job,
                        &BootseerConfig::baseline(),
                        &mut w,
                        StartupKind::Full,
                        1000 + s as u64,
                    );
                    stats::max_median_ratio(&o.install_durations)
                })
                .collect();
            (gpus, BoxSummary::of(&ratios))
        })
        .collect();
    Fig06 { rows }
}

impl Fig06 {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "gpus".to_string(),
            "max/median med".to_string(),
            "q1..q3".to_string(),
            "worst".to_string(),
        ]];
        for (gpus, b) in &self.rows {
            rows.push(vec![
                gpus.to_string(),
                format!("{:.2}", b.median),
                format!("{:.2}..{:.2}", b.q1, b.q3),
                format!("{:.2}", b.max),
            ]);
        }
        format!(
            "{}paper: ~1.0 small → ~1.5 at 1,000+ GPUs, extremes 4x+\n",
            human::table(&rows)
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .rows
            .iter()
            .map(|(g, b)| {
                let mut o = Json::obj();
                o.set("gpus", *g as u64).set("median", b.median).set("max", b.max);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("scales", Json::Arr(arr));
        j
    }
}

// --------------------------------------------------------------- Fig 7 --

pub struct Fig07 {
    pub durations: Vec<f64>,
    pub hist: Histogram,
}

/// The 11,520-GPU (1,440-node) job's install-duration distribution.
pub fn fig07(seed: u64) -> Fig07 {
    let job = JobConfig::paper_moe(11_520);
    // The §3.4 job's install script was lighter than the §5 MoE job's.
    let job = JobConfig { env_packages: 8, env_install_cpu_mean_s: 2.5, ..job };
    let mut w = World::new();
    let o = run_startup(
        11_520,
        0,
        &ClusterConfig::default(),
        &job,
        &BootseerConfig::baseline(),
        &mut w,
        StartupKind::Full,
        seed,
    );
    let hi = stats::max(&o.install_durations) * 1.02;
    let hist = Histogram::build(&o.install_durations, 0.0, hi.max(1.0), 24);
    Fig07 { durations: o.install_durations, hist }
}

impl Fig07 {
    pub fn render(&self) -> String {
        let med = stats::median(&self.durations);
        let frac60 = stats::fraction_le(&self.durations, med * 1.4);
        format!(
            "{}\nnodes={} median={} p99={} max={} (≤1.4x-median fraction: {:.1}%)\n\
             paper: most nodes ≤60s; <1% up to ~92s; all 1,440 servers wait for the slowest\n",
            self.hist.render(48),
            self.durations.len(),
            human::secs(med),
            human::secs(stats::quantile(&self.durations, 0.99)),
            human::secs(stats::max(&self.durations)),
            100.0 * frac60,
        )
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("n_nodes", self.durations.len())
            .set("median", stats::median(&self.durations))
            .set("p99", stats::quantile(&self.durations, 0.99))
            .set("max", stats::max(&self.durations));
        j
    }
}

// ---------------------------------------------------------- Fig 12 / 13 --

pub struct ScalePoint {
    pub gpus: u32,
    pub baseline: StartupOutcome,
    pub bootseer: StartupOutcome,
}

pub struct Fig12 {
    pub points: Vec<ScalePoint>,
}

/// End-to-end startup, baseline vs warm BootSeer, at the §5.1 scales,
/// averaged over `reps` runs (paper: 3 independent runs).
pub fn fig12(reps: u32) -> Fig12 {
    let scales = [16u32, 32, 48, 64, 128];
    let cluster = ClusterConfig::default();
    let points = scales
        .iter()
        .map(|&gpus| {
            let job = JobConfig::paper_moe(gpus);
            // Representative run = median rep by worker_phase.
            let mut base_runs: Vec<StartupOutcome> = (0..reps)
                .map(|r| {
                    let mut w = World::new();
                    run_startup(
                        gpus as u64,
                        r,
                        &cluster,
                        &job,
                        &BootseerConfig::baseline(),
                        &mut w,
                        StartupKind::Full,
                        77 + r as u64,
                    )
                })
                .collect();
            let mut boot_runs: Vec<StartupOutcome> = (0..reps)
                .map(|r| {
                    let mut w = World::new();
                    // Warm-up: record + cache.
                    run_startup(
                        gpus as u64,
                        0,
                        &cluster,
                        &job,
                        &BootseerConfig::bootseer(),
                        &mut w,
                        StartupKind::Full,
                        7 + r as u64,
                    );
                    run_startup(
                        gpus as u64,
                        1,
                        &cluster,
                        &job,
                        &BootseerConfig::bootseer(),
                        &mut w,
                        StartupKind::Full,
                        77 + r as u64,
                    )
                })
                .collect();
            let med = |v: &mut Vec<StartupOutcome>| {
                v.sort_by(|a, b| a.worker_phase_s.partial_cmp(&b.worker_phase_s).unwrap());
                v.remove(v.len() / 2)
            };
            ScalePoint { gpus, baseline: med(&mut base_runs), bootseer: med(&mut boot_runs) }
        })
        .collect();
    Fig12 { points }
}

impl Fig12 {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "gpus".to_string(),
            "baseline".to_string(),
            "bootseer".to_string(),
            "speedup".to_string(),
        ]];
        for p in &self.points {
            rows.push(vec![
                p.gpus.to_string(),
                human::secs(p.baseline.worker_phase_s),
                human::secs(p.bootseer.worker_phase_s),
                human::ratio(p.baseline.worker_phase_s / p.bootseer.worker_phase_s),
            ]);
        }
        format!(
            "{}paper: ~2x reduction at every scale, growing toward 128 GPUs\n",
            human::table(&rows)
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("gpus", p.gpus as u64)
                    .set("baseline_s", p.baseline.worker_phase_s)
                    .set("bootseer_s", p.bootseer.worker_phase_s);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("points", Json::Arr(arr));
        j
    }

    /// Fig 13 is the per-stage breakdown of the same runs.
    pub fn render_stages(&self) -> String {
        let mut rows = vec![vec![
            "gpus".to_string(),
            "image b/B".to_string(),
            "env b/B".to_string(),
            "init b/B".to_string(),
        ]];
        for p in &self.points {
            let cell = |s: Stage| {
                format!(
                    "{} / {} ({})",
                    human::secs(p.baseline.stage_duration(s)),
                    human::secs(p.bootseer.stage_duration(s)),
                    human::ratio(
                        p.baseline.stage_duration(s) / p.bootseer.stage_duration(s).max(1e-9)
                    )
                )
            };
            rows.push(vec![
                p.gpus.to_string(),
                cell(Stage::ImageLoading),
                cell(Stage::EnvSetup),
                cell(Stage::ModelInit),
            ]);
        }
        format!(
            "{}paper: image 4-10x (growing with scale), env ~2x, model-init ~1.6x\n",
            human::table(&rows)
        )
    }

    pub fn stages_json(&self) -> Json {
        let arr: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("gpus", p.gpus as u64);
                for (key, s) in [
                    ("image", Stage::ImageLoading),
                    ("env", Stage::EnvSetup),
                    ("init", Stage::ModelInit),
                ] {
                    o.set(&format!("{key}_baseline_s"), p.baseline.stage_duration(s))
                        .set(&format!("{key}_bootseer_s"), p.bootseer.stage_duration(s));
                }
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("points", Json::Arr(arr));
        j
    }
}

// ----------------------------------------------- Overlap-mode sweep --

pub struct OverlapPoint {
    pub gpus: u32,
    /// Median worker-phase seconds per mode, in [`OverlapMode::ALL`] order
    /// (Sequential, Overlapped, Speculative).
    pub worker_s: [f64; 3],
}

pub struct OverlapSweep {
    pub points: Vec<OverlapPoint>,
}

/// Worker-phase startup across the stage-graph overlap modes (warm
/// BootSeer configuration) at the §5.1 scales; `reps` runs per cell, the
/// median is reported. `Sequential` is the paper-faithful pipeline;
/// `Overlapped` chains stages per node; `Speculative` additionally stages
/// the image hot set + env archive during Allocation.
pub fn overlap_sweep(reps: u32) -> OverlapSweep {
    let scales = [16u32, 32, 64, 128];
    let cluster = ClusterConfig::default();
    let points = scales
        .iter()
        .map(|&gpus| {
            let job = JobConfig::paper_moe(gpus);
            let mut worker_s = [0.0f64; 3];
            for (mi, &mode) in OverlapMode::ALL.iter().enumerate() {
                let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() };
                let mut runs: Vec<f64> = (0..reps.max(1))
                    .map(|r| {
                        let mut w = World::new();
                        // Warm-up: record the hot set + create the cache.
                        run_startup(
                            gpus as u64,
                            0,
                            &cluster,
                            &job,
                            &cfg,
                            &mut w,
                            StartupKind::Full,
                            7 + r as u64,
                        );
                        run_startup(
                            gpus as u64,
                            1,
                            &cluster,
                            &job,
                            &cfg,
                            &mut w,
                            StartupKind::Full,
                            77 + r as u64,
                        )
                        .worker_phase_s
                    })
                    .collect();
                runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                worker_s[mi] = runs[runs.len() / 2];
            }
            OverlapPoint { gpus, worker_s }
        })
        .collect();
    OverlapSweep { points }
}

impl OverlapSweep {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "gpus".to_string(),
            "sequential".to_string(),
            "overlapped".to_string(),
            "speculative".to_string(),
            "spec speedup".to_string(),
        ]];
        for p in &self.points {
            let [seq, ovl, spec] = p.worker_s;
            rows.push(vec![
                p.gpus.to_string(),
                human::secs(seq),
                human::secs(ovl),
                human::secs(spec),
                human::ratio(seq / spec.max(1e-9)),
            ]);
        }
        let ordered = self.points.iter().all(|p| {
            p.worker_s[1] <= p.worker_s[0] + 1e-9 && p.worker_s[2] <= p.worker_s[1] + 1e-9
        });
        format!(
            "{}stage-graph gating Sequential ≥ Overlapped ≥ Speculative: {}\n",
            human::table(&rows),
            if ordered { "holds at every scale" } else { "VIOLATED — see table" }
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("gpus", p.gpus as u64)
                    .set("sequential_s", p.worker_s[0])
                    .set("overlapped_s", p.worker_s[1])
                    .set("speculative_s", p.worker_s[2]);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("points", Json::Arr(arr));
        j
    }
}

// ------------------------------------------ Fig 16: wasted GPU time --

/// One overlap mode's wasted-GPU-time numbers under fault injection.
pub struct FaultsPoint {
    pub mode: OverlapMode,
    /// Which BootSeer feature set the mode ran (the Sequential point is
    /// the paper baseline; the overlap mitigations run warm BootSeer).
    pub config: &'static str,
    /// Wasted share of all GPU time: (startup + rollback) / total.
    pub wasted_fraction: f64,
    /// Same, restricted to jobs of 128+ GPUs.
    pub wasted_fraction_ge128: f64,
    pub startup_gpu_hours: f64,
    pub lost_gpu_hours: f64,
    pub train_gpu_hours: f64,
    pub fault_restarts: u64,
}

/// The wasted-GPU-time sweep (Fig 16, `BENCH_faults.json`).
pub struct FaultsSweep {
    pub points: Vec<FaultsPoint>,
    pub n_jobs: usize,
    pub seed: u64,
}

/// Trace parameters of the canonical fig16 run: chosen so the paper
/// baseline lands on the "more than 3.5% of GPU time is wasted" headline
/// (2–5% band) under [`FaultConfig::paper`].
pub const FAULTS_SWEEP_SEED: u64 = 10;
pub const FAULTS_SWEEP_JOBS: usize = 150;

/// Replay one synthetic week per overlap mode under fault injection and
/// measure the wasted GPU time (startup overhead + checkpoint-rollback
/// losses). The Sequential point runs the paper-faithful baseline feature
/// set — reproducing the ~3.5% wasted-GPU-time headline at
/// [`FaultConfig::paper`] — while Overlapped/Speculative run the warm
/// BootSeer feature set, showing the mitigations cutting the wasted share.
/// The crash schedule (phase 1) is identical across modes — the startup
/// estimates that size scheduler segments don't depend on the feature set
/// — so the comparison isolates the startup-side savings.
pub fn wasted_gpu_time_sweep(seed: u64, n_jobs: usize, faults: &FaultConfig) -> FaultsSweep {
    let trace = gen_trace(seed, n_jobs, 7.0 * 86400.0);
    let cluster = ClusterConfig::default();
    let points = OverlapMode::ALL
        .iter()
        .map(|&mode| {
            let (cfg, config) = match mode {
                OverlapMode::Sequential => (BootseerConfig::baseline(), "baseline"),
                _ => (
                    BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() },
                    "bootseer",
                ),
            };
            let r = replay_cluster(
                &trace,
                &cluster,
                &cfg,
                seed,
                &ReplayOptions { faults: faults.clone(), ..ReplayOptions::default() },
            );
            // ≥128-GPU slice from the per-job waste accounting.
            let mut wasted128 = 0.0;
            let mut train128 = 0.0;
            for j in &r.jobs {
                if j.job.gpus >= 128 {
                    wasted128 += j.wasted_gpu_s / 3600.0;
                    train128 += j.job.gpus as f64 * j.job.train_hours;
                }
            }
            FaultsPoint {
                mode,
                config,
                wasted_fraction: r.wasted_fraction(),
                wasted_fraction_ge128: if train128 > 0.0 {
                    wasted128 / (wasted128 + train128)
                } else {
                    0.0
                },
                startup_gpu_hours: r.startup_gpu_hours,
                lost_gpu_hours: r.lost_train_gpu_hours,
                train_gpu_hours: r.train_gpu_hours,
                fault_restarts: r.fault_restarts,
            }
        })
        .collect();
    FaultsSweep { points, n_jobs, seed }
}

impl FaultsSweep {
    pub fn point(&self, mode: OverlapMode) -> &FaultsPoint {
        self.points.iter().find(|p| p.mode == mode).expect("all modes swept")
    }

    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "mode".to_string(),
            "config".to_string(),
            "wasted".to_string(),
            "wasted@128+".to_string(),
            "startup GPU-h".to_string(),
            "rollback GPU-h".to_string(),
            "restarts".to_string(),
        ]];
        for p in &self.points {
            rows.push(vec![
                p.mode.name().to_string(),
                p.config.to_string(),
                format!("{:.2}%", 100.0 * p.wasted_fraction),
                format!("{:.2}%", 100.0 * p.wasted_fraction_ge128),
                format!("{:.0}", p.startup_gpu_hours),
                format!("{:.0}", p.lost_gpu_hours),
                p.fault_restarts.to_string(),
            ]);
        }
        format!(
            "{}paper: \"more than 3.5% of GPU time is wasted due to startup overhead alone\"\n",
            human::table(&rows)
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("mode", p.mode.name())
                    .set("config", p.config)
                    .set("wasted_fraction", p.wasted_fraction)
                    .set("wasted_fraction_ge128", p.wasted_fraction_ge128)
                    .set("startup_gpu_hours", p.startup_gpu_hours)
                    .set("lost_gpu_hours", p.lost_gpu_hours)
                    .set("train_gpu_hours", p.train_gpu_hours)
                    .set("fault_restarts", p.fault_restarts);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("modes", Json::Arr(arr))
            .set("n_jobs", self.n_jobs)
            .set("seed", self.seed);
        j
    }
}

// ------------------------------- Cache economics: capacity knee curve --

/// One capacity point of the fleet cache-economics sweep.
pub struct CachePoint {
    /// Per-node warm-cache capacity in bytes (`u64::MAX` = unbounded).
    pub capacity_bytes: u64,
    /// Human label for the capacity point ("3g", ..., "unbounded").
    pub capacity: &'static str,
    /// Wasted share of all GPU time: (startup + rollback) / total.
    pub wasted_fraction: f64,
    pub startup_gpu_hours: f64,
    /// Warm-cache hit rate across the fleet: credited / demanded bytes.
    pub hit_rate: f64,
    /// Load-shed rate at the registry / cluster-cache tiers:
    /// shed events / admission checks.
    pub shed_rate: f64,
    /// Bytes evicted under capacity pressure across all startups.
    pub evicted_bytes: u64,
    pub fault_restarts: u64,
}

/// The cache-economics sweep (`BENCH_cache.json`): fleet wasted GPU time
/// vs per-node cache capacity under storm-tier fault traffic.
pub struct CacheSweep {
    pub points: Vec<CachePoint>,
    pub n_jobs: usize,
    pub seed: u64,
}

/// Capacities swept for the knee curve, smallest first. The smallest
/// point still holds a typical env snapshot plus image hot set; the
/// largest finite point retains most working sets so the curve visibly
/// plateaus toward the unbounded endpoint.
pub const CACHE_SWEEP_CAPACITIES: [(&str, u64); 4] = [
    ("3g", 3_000_000_000),
    ("8g", 8_000_000_000),
    ("24g", 24_000_000_000),
    ("unbounded", u64::MAX),
];

/// Jobs in the canonical cache-economics run: smaller than the fig16
/// trace because each of the four capacity points replays the whole week
/// under storm-tier restart traffic.
pub const CACHE_SWEEP_JOBS: usize = 50;

/// Fault tier for the cache-economics sweep: [`FaultConfig::storm`]'s
/// finite registry/cache concurrency slots (so load-shedding actually
/// fires) combined with a hotter crash hazard and mostly same-node
/// restarts. Production storm rates fire too few warm restarts on
/// bench-sized traces for the capacity knee to emerge from eviction
/// pressure; the hotter hazard keeps the knee deterministic at
/// [`CACHE_SWEEP_JOBS`]-job scale.
pub fn cache_sweep_faults() -> FaultConfig {
    FaultConfig { hazard_per_gpu_hour: 2.0e-3, relocate_prob: 0.2, ..FaultConfig::storm() }
}

/// Replay one synthetic week per cache capacity (eviction policy: LRU)
/// under storm-tier faults and measure the fleet economics: wasted
/// fraction, warm-cache hit rate, shed rate, evicted bytes. The crash
/// schedule (phase 1) is identical across capacities — capacity only
/// changes what survives in the warm caches between restart segments —
/// so the sweep isolates the eviction cost: every byte a larger cache
/// retains is a byte a smaller cache must refetch, which is what bends
/// the wasted-fraction knee.
pub fn cache_economics_sweep(seed: u64, n_jobs: usize, faults: &FaultConfig) -> CacheSweep {
    let trace = gen_trace(seed, n_jobs, 7.0 * 86400.0);
    let cluster = ClusterConfig::default();
    let points = CACHE_SWEEP_CAPACITIES
        .iter()
        .map(|&(name, cap)| {
            let cfg = BootseerConfig {
                cache_capacity_bytes: cap,
                cache_policy: CachePolicy::Lru,
                ..BootseerConfig::bootseer()
            };
            let r = replay_cluster(
                &trace,
                &cluster,
                &cfg,
                seed,
                &ReplayOptions { faults: faults.clone(), ..ReplayOptions::default() },
            );
            CachePoint {
                capacity_bytes: cap,
                capacity: name,
                wasted_fraction: r.wasted_fraction(),
                startup_gpu_hours: r.startup_gpu_hours,
                hit_rate: r.hit_rate(),
                shed_rate: r.shed_rate(),
                evicted_bytes: r.evicted_bytes,
                fault_restarts: r.fault_restarts,
            }
        })
        .collect();
    CacheSweep { points, n_jobs, seed }
}

impl CacheSweep {
    pub fn point(&self, capacity: &str) -> &CachePoint {
        self.points.iter().find(|p| p.capacity == capacity).expect("capacity swept")
    }

    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "capacity".to_string(),
            "wasted".to_string(),
            "startup GPU-h".to_string(),
            "hit rate".to_string(),
            "shed rate".to_string(),
            "evicted".to_string(),
            "restarts".to_string(),
        ]];
        for p in &self.points {
            rows.push(vec![
                p.capacity.to_string(),
                format!("{:.2}%", 100.0 * p.wasted_fraction),
                format!("{:.0}", p.startup_gpu_hours),
                format!("{:.1}%", 100.0 * p.hit_rate),
                format!("{:.1}%", 100.0 * p.shed_rate),
                human::bytes(p.evicted_bytes),
                p.fault_restarts.to_string(),
            ]);
        }
        let knee =
            self.points.windows(2).all(|w| w[1].wasted_fraction < w[0].wasted_fraction);
        format!(
            "{}capacity knee (wasted fraction strictly falls toward unbounded): {}\n",
            human::table(&rows),
            if knee { "holds" } else { "VIOLATED — see table" }
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("capacity", p.capacity)
                    .set("wasted_fraction", p.wasted_fraction)
                    .set("startup_gpu_hours", p.startup_gpu_hours)
                    .set("hit_rate", p.hit_rate)
                    .set("shed_rate", p.shed_rate)
                    .set("evicted_bytes", p.evicted_bytes)
                    .set("fault_restarts", p.fault_restarts);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("points", Json::Arr(arr)).set("n_jobs", self.n_jobs).set("seed", self.seed);
        j
    }
}

// ------------------------------------------------ topology fragmentation --

/// One fragmentation point: the warm 128-GPU startup with its 16 nodes
/// spread over `racks_spanned` racks of the topology tree.
pub struct TopologyPoint {
    pub racks_spanned: u32,
    /// Warm startup end-to-end (alloc + worker phases), simulated seconds.
    pub total_s: f64,
    /// Worker phase only (image + env + model init), simulated seconds.
    pub worker_s: f64,
    /// Share of each node's swarm peers that sit across the spine — pure
    /// placement arithmetic, the monotone x-axis of the figure.
    pub cross_frac: f64,
}

/// The fragmentation sweep (`BENCH_topology.json`): warm 128-GPU startup
/// time vs how many racks the gang's 16 nodes span, on a 16-rack tree
/// whose spine core is oversubscribed 10× against the node NICs while the
/// rack uplinks stay inert. Startup time must increase strictly with
/// fragmentation — the invariant the `micro_topology` bench and CI gate.
pub struct TopologySweep {
    pub points: Vec<TopologyPoint>,
    pub seed: u64,
}

/// Rack counts swept: 1 (whole gang in one rack, zero spine traffic) up
/// to 16 (every node alone in its rack, all swarm traffic cross-spine).
pub const FRAG_SWEEP_RACKS: [u32; 5] = [1, 2, 4, 8, 16];

/// Sweep placement fragmentation at the paper's flagship 128-GPU scale:
/// a cold startup records the image hot set + env cache, then the
/// measured warm startup swarm-fetches from its peers — and the placement
/// decides how much of that traffic crosses the oversubscribed spine.
pub fn fragmentation_sweep(seed: u64) -> TopologySweep {
    let job = JobConfig::paper_moe(128);
    let cluster = ClusterConfig {
        racks: 16,
        spines: 4,
        // Fat rack uplinks: only the spine core binds, so the sweep
        // isolates the cross-rack share of the swarm traffic.
        rack_uplink_bps: 1.0e15,
        spine_core_bps: d::NODE_NIC_BPS / 10.0,
        ..ClusterConfig::default()
    };
    let cfg = BootseerConfig::bootseer();
    let nodes = job.nodes(&cluster) as usize;
    let points = FRAG_SWEEP_RACKS
        .iter()
        .map(|&f| {
            let placement: Vec<u32> =
                (0..nodes).map(|i| (i as u32 * f) / nodes as u32).collect();
            let ctx = || StartupContext {
                alloc_s: d::ALLOC_BASE_S + 0.02 * nodes as f64,
                placement: Some(Arc::new(placement.clone())),
                ..StartupContext::default()
            };
            let mut world = World::new();
            run_startup_with(
                1,
                0,
                &cluster,
                &job,
                &cfg,
                &mut world,
                StartupKind::Full,
                seed,
                ctx(),
            );
            let warm = run_startup_with(
                1,
                1,
                &cluster,
                &job,
                &cfg,
                &mut world,
                StartupKind::Full,
                seed.wrapping_add(1),
                ctx(),
            );
            let in_rack = nodes as f64 / f as f64 - 1.0;
            let peers = (nodes - 1) as f64;
            TopologyPoint {
                racks_spanned: f,
                total_s: warm.total_s,
                worker_s: warm.worker_phase_s,
                cross_frac: (peers - in_rack) / peers,
            }
        })
        .collect();
    TopologySweep { points, seed }
}

impl TopologySweep {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "racks".to_string(),
            "cross peers".to_string(),
            "warm worker s".to_string(),
            "warm total s".to_string(),
        ]];
        for p in &self.points {
            rows.push(vec![
                p.racks_spanned.to_string(),
                format!("{:.1}%", 100.0 * p.cross_frac),
                format!("{:.2}", p.worker_s),
                format!("{:.2}", p.total_s),
            ]);
        }
        let mono = self.points.windows(2).all(|w| w[1].worker_s > w[0].worker_s);
        format!(
            "{}fragmentation tax (startup strictly slows as the gang spreads): {}\n",
            human::table(&rows),
            if mono { "holds" } else { "VIOLATED — see table" }
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("racks_spanned", p.racks_spanned)
                    .set("cross_frac", p.cross_frac)
                    .set("worker_s", p.worker_s)
                    .set("total_s", p.total_s);
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("points", Json::Arr(arr)).set("seed", self.seed);
        j
    }
}

// -------------------------------------------------------------- Fig 14 --

pub struct Fig14 {
    pub baseline: Vec<f64>,
    pub bootseer: Vec<f64>,
}

/// Install-duration distributions across the 128-GPU job's nodes.
pub fn fig14(seed: u64) -> Fig14 {
    let job = JobConfig::paper_moe(128);
    let cluster = ClusterConfig::default();
    let mut w0 = World::new();
    let base = run_startup(
        1,
        0,
        &cluster,
        &job,
        &BootseerConfig::baseline(),
        &mut w0,
        StartupKind::Full,
        seed,
    );
    let mut wb = World::new();
    let boot_cfg = BootseerConfig::bootseer();
    run_startup(1, 0, &cluster, &job, &boot_cfg, &mut wb, StartupKind::Full, seed);
    let boot = run_startup(1, 1, &cluster, &job, &boot_cfg, &mut wb, StartupKind::Full, seed + 1);
    Fig14 { baseline: base.install_durations, bootseer: boot.install_durations }
}

impl Fig14 {
    pub fn render(&self) -> String {
        let b = BoxSummary::of(&self.baseline);
        let o = BoxSummary::of(&self.bootseer);
        format!(
            "baseline  {}\nbootseer  {}\npaper: BootSeer removes both the overhead and the spread (whiskers → min/max)\n",
            b.line(),
            o.line()
        )
    }

    pub fn to_json(&self) -> Json {
        let b = BoxSummary::of(&self.baseline);
        let o = BoxSummary::of(&self.bootseer);
        let mut j = Json::obj();
        j.set("baseline_median", b.median)
            .set("baseline_max", b.max)
            .set("bootseer_median", o.median)
            .set("bootseer_max", o.max);
        j
    }
}

// ------------------------------------------ Artifact-layer scenarios --

/// One scale point of the artifact-layer sweep: cold / warm / delta
/// materialization of the same job's artifacts, plus the dedup variant.
pub struct ArtifactPoint {
    pub nodes: u32,
    pub gpus: u32,
    /// Worker-phase seconds: cold start, warm restart (hot set + env
    /// archive resident), warm restart with delta resume.
    pub cold_s: f64,
    pub warm_s: f64,
    pub delta_s: f64,
    /// Foreground bytes fetched in each scenario (deterministic).
    pub cold_bytes: u64,
    pub warm_bytes: u64,
    pub delta_bytes: u64,
    /// Cold start with cross-artifact dedup on (env chunks shared with
    /// the image hot set served locally).
    pub dedup_bytes: u64,
}

impl ArtifactPoint {
    pub fn warm_bytes_fraction(&self) -> f64 {
        self.warm_bytes as f64 / self.cold_bytes.max(1) as f64
    }

    pub fn delta_bytes_fraction(&self) -> f64 {
        self.delta_bytes as f64 / self.cold_bytes.max(1) as f64
    }

    pub fn dedup_bytes_fraction(&self) -> f64 {
        self.dedup_bytes as f64 / self.cold_bytes.max(1) as f64
    }
}

pub struct ArtifactSweep {
    pub points: Vec<ArtifactPoint>,
}

/// Cold vs warm vs delta-resume materialization through the unified
/// artifact layer, at 16 and 128 nodes. "Cold" is a warm-*world* startup
/// (records + caches exist cluster-wide) on freshly allocated nodes;
/// "warm" additionally holds the image hot set and env archive on every
/// node's local disk (the same-nodes restart); "delta" also keeps the
/// checkpoint-shard chunks the rollback did not rewrite. `reps` runs per
/// cell, median seconds reported; byte counts are deterministic.
pub fn artifact_sweep(reps: u32) -> ArtifactSweep {
    use crate::artifact::manifest::ArtifactManifest;
    use crate::artifact::CacheState;
    use crate::ckpt::resume::retained_resume_bytes_per_node;
    use crate::env::packages::PackageSet;
    use crate::image::spec::ImageSpec;
    use crate::startup::{run_startup_with, StartupContext};

    let cluster = ClusterConfig::default();
    let points = [16u32, 128]
        .iter()
        .map(|&nodes| {
            let gpus = nodes * 8;
            let job = JobConfig::paper_moe(gpus);
            let img = ImageSpec::synth(
                job.image_identity_seed(1),
                job.image_bytes,
                job.image_block_bytes,
                job.image_hot_fraction,
            );
            let sig = PackageSet::synth(&job, job.env_identity_seed(1)).signature();
            let retained = retained_resume_bytes_per_node(&job, &cluster);
            let warm_cache = || {
                let mut c = CacheState::new();
                c.insert_shared_artifact(
                    ArtifactManifest::image_hot_id(img.digest),
                    img.hot_bytes(),
                );
                c.insert_shared_artifact(
                    ArtifactManifest::env_snapshot_id(sig),
                    job.env_cache_bytes,
                );
                c
            };
            let delta_cache = || {
                let mut c = warm_cache();
                c.insert_shared_artifact(ArtifactManifest::ckpt_shard_id(&job), retained);
                c
            };
            // One measured cell: warm up the world (record + env cache),
            // then run the scenario from the given cache state.
            let cell = |cfg: &BootseerConfig, cache: CacheState, r: u32| {
                let mut w = World::new();
                run_startup(1, 0, &cluster, &job, cfg, &mut w, StartupKind::Full, 7 + r as u64);
                run_startup_with(
                    1,
                    1,
                    &cluster,
                    &job,
                    cfg,
                    &mut w,
                    StartupKind::Full,
                    77 + r as u64,
                    StartupContext { queue_s: 0.0, alloc_s: 2.0, cache, ..Default::default() },
                )
            };
            let median = |mut xs: Vec<f64>| {
                xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                xs[xs.len() / 2]
            };
            let boot = BootseerConfig::bootseer();
            let delta_cfg = BootseerConfig { delta_resume: true, ..BootseerConfig::bootseer() };
            let dedup_cfg =
                BootseerConfig { artifact_dedup: true, ..BootseerConfig::bootseer() };
            let mut cold_t = Vec::new();
            let mut warm_t = Vec::new();
            let mut delta_t = Vec::new();
            let mut bytes = (0u64, 0u64, 0u64, 0u64);
            for r in 0..reps.max(1) {
                let cold = cell(&boot, CacheState::new(), r);
                let warm = cell(&boot, warm_cache(), r);
                let delta = cell(&delta_cfg, delta_cache(), r);
                let dedup = cell(&dedup_cfg, CacheState::new(), r);
                cold_t.push(cold.worker_phase_s);
                warm_t.push(warm.worker_phase_s);
                delta_t.push(delta.worker_phase_s);
                bytes = (
                    cold.fetched_bytes,
                    warm.fetched_bytes,
                    delta.fetched_bytes,
                    dedup.fetched_bytes,
                );
            }
            ArtifactPoint {
                nodes,
                gpus,
                cold_s: median(cold_t),
                warm_s: median(warm_t),
                delta_s: median(delta_t),
                cold_bytes: bytes.0,
                warm_bytes: bytes.1,
                delta_bytes: bytes.2,
                dedup_bytes: bytes.3,
            }
        })
        .collect();
    ArtifactSweep { points }
}

impl ArtifactSweep {
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "nodes".to_string(),
            "cold".to_string(),
            "warm".to_string(),
            "delta".to_string(),
            "cold bytes".to_string(),
            "warm bytes".to_string(),
            "delta bytes".to_string(),
            "dedup bytes".to_string(),
        ]];
        for p in &self.points {
            rows.push(vec![
                p.nodes.to_string(),
                human::secs(p.cold_s),
                human::secs(p.warm_s),
                human::secs(p.delta_s),
                human::bytes(p.cold_bytes),
                human::bytes(p.warm_bytes),
                human::bytes(p.delta_bytes),
                human::bytes(p.dedup_bytes),
            ]);
        }
        let ordered = self.points.iter().all(|p| {
            p.delta_bytes < p.warm_bytes
                && p.warm_bytes < p.cold_bytes
                && p.dedup_bytes < p.cold_bytes
        });
        format!(
            "{}warm and delta restarts re-fetch strictly fewer bytes; dedup serves shared chunks locally: {}\n",
            human::table(&rows),
            if ordered { "holds at every scale" } else { "VIOLATED — see table" }
        )
    }

    pub fn to_json(&self) -> Json {
        let arr: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                let mut o = Json::obj();
                o.set("nodes", u64::from(p.nodes))
                    .set("gpus", u64::from(p.gpus))
                    .set("cold_s", p.cold_s)
                    .set("warm_s", p.warm_s)
                    .set("delta_s", p.delta_s)
                    .set("cold_bytes", p.cold_bytes)
                    .set("warm_bytes", p.warm_bytes)
                    .set("delta_bytes", p.delta_bytes)
                    .set("dedup_bytes", p.dedup_bytes)
                    .set("warm_bytes_fraction", p.warm_bytes_fraction())
                    .set("delta_bytes_fraction", p.delta_bytes_fraction())
                    .set("dedup_bytes_fraction", p.dedup_bytes_fraction());
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("points", Json::Arr(arr));
        j
    }
}

/// Figure "optimize frontier": the closed-loop mitigation search's Pareto
/// frontier of wasted GPU-time fraction vs cache + prefetch byte budget
/// (see `docs/optimize.md`). `quick` selects the small smoke-sized search
/// instead of the canonical one; the report is deterministic for a given
/// `(seed, quick)` at any `threads`.
pub fn optimize_frontier(
    seed: u64,
    threads: usize,
    quick: bool,
) -> crate::optimize::OptimizeReport {
    let params = if quick {
        crate::optimize::OptimizeParams::quick(seed, threads)
    } else {
        crate::optimize::OptimizeParams::canonical(seed, threads)
    };
    crate::optimize::run_optimize(&params)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_replay() -> ReplayResult {
        let trace = gen_trace(5, 40, 86400.0);
        replay(&trace, &ClusterConfig::default(), &BootseerConfig::baseline(), 5)
    }

    #[test]
    fn fig01_in_band() {
        let r = tiny_replay();
        let f = fig01(&r);
        assert!((0.002..0.2).contains(&f.fraction()), "{}", f.fraction());
        assert!(f.render().contains("startup fraction"));
    }

    #[test]
    fn fig03_monotone_with_scale() {
        let r = tiny_replay();
        let f = fig03(&r);
        assert_eq!(f.rows.len(), SCALE_BUCKETS.len());
        // Node-level ≤ job-level wherever both exist.
        for (_, j, n) in &f.rows {
            if let (Some(j), Some(n)) = (j, n) {
                assert!(n.median <= j.median + 1e-6);
            }
        }
        assert!(!f.render().is_empty());
    }

    #[test]
    fn fig04_small_jobs_one_startup() {
        let r = tiny_replay();
        let f = fig04(&r);
        let (_, first_box, n) = &f.rows[0];
        assert!(*n > 0);
        assert!(first_box.as_ref().unwrap().median <= 2.0);
    }

    #[test]
    fn fig05_has_worker_stages() {
        let r = tiny_replay();
        let f = fig05(&r);
        let stages: Vec<Stage> = f.rows.iter().map(|(s, _)| *s).collect();
        for s in Stage::WORKER_PHASE {
            assert!(stages.contains(&s), "{s:?} missing");
        }
    }

    #[test]
    fn fig06_ratio_grows() {
        let f = fig06(3);
        let small = f.rows[0].1.median;
        let large = f.rows[4].1.median; // 4096 GPUs
        assert!(large > small, "straggler ratio should grow: {small} vs {large}");
        assert!(large > 1.15, "large-scale ratio {large}");
    }

    #[test]
    fn fig12_speedup_band() {
        let f = fig12(1);
        for p in &f.points {
            let r = p.baseline.worker_phase_s / p.bootseer.worker_phase_s;
            assert!((1.4..4.0).contains(&r), "gpus={} ratio={r}", p.gpus);
        }
        assert!(!f.render_stages().is_empty());
    }

    #[test]
    fn overlap_sweep_ordering() {
        let f = overlap_sweep(1);
        assert_eq!(f.points.len(), 4);
        for p in &f.points {
            // Monotone at every scale (ties tolerated off the 128 anchor).
            assert!(
                p.worker_s[1] <= p.worker_s[0] + 1e-9,
                "gpus={}: overlapped {} vs sequential {}",
                p.gpus,
                p.worker_s[1],
                p.worker_s[0]
            );
            assert!(
                p.worker_s[2] <= p.worker_s[1] + 1e-9,
                "gpus={}: speculative {} vs overlapped {}",
                p.gpus,
                p.worker_s[2],
                p.worker_s[1]
            );
        }
        // Strict reduction at the paper's flagship 128-GPU scale.
        let p128 = f.points.iter().find(|p| p.gpus == 128).unwrap();
        assert!(p128.worker_s[1] < p128.worker_s[0]);
        assert!(p128.worker_s[2] < p128.worker_s[1]);
        assert!(!f.render().is_empty());
    }

    #[test]
    fn wasted_sweep_mitigations_cut_waste() {
        // Small-trace smoke of the fig16 machinery (the canonical band
        // check runs in the fig16 bench at FAULTS_SWEEP_JOBS): the warm
        // BootSeer overlap modes must waste less than the baseline, the
        // crash schedule must be identical across modes, and the sweep
        // must be reproducible.
        let f = wasted_gpu_time_sweep(6, 50, &FaultConfig::paper());
        assert_eq!(f.points.len(), 3);
        let seq = f.point(OverlapMode::Sequential);
        let ovl = f.point(OverlapMode::Overlapped);
        let spec = f.point(OverlapMode::Speculative);
        assert_eq!(seq.fault_restarts, spec.fault_restarts, "same crash schedule");
        assert_eq!(seq.lost_gpu_hours.to_bits(), spec.lost_gpu_hours.to_bits());
        assert!(
            ovl.wasted_fraction < seq.wasted_fraction,
            "overlapped {} vs sequential {}",
            ovl.wasted_fraction,
            seq.wasted_fraction
        );
        assert!(
            spec.wasted_fraction < seq.wasted_fraction,
            "speculative {} vs sequential {}",
            spec.wasted_fraction,
            seq.wasted_fraction
        );
        assert!(seq.wasted_fraction > 0.0 && seq.wasted_fraction < 0.5);
        assert!(!f.render().is_empty());
        let again = wasted_gpu_time_sweep(6, 50, &FaultConfig::paper());
        assert_eq!(
            again.point(OverlapMode::Sequential).wasted_fraction.to_bits(),
            seq.wasted_fraction.to_bits(),
            "sweep reproducible bit-for-bit"
        );
    }

    #[test]
    fn cache_sweep_knee_strictly_decreases_and_plateaus() {
        // Small-trace run of the BENCH_cache machinery (the canonical
        // run is the micro_cache bench at CACHE_SWEEP_JOBS): wasted
        // fraction must strictly fall with capacity, eviction pressure
        // must vanish at the unbounded endpoint, and the sweep must be
        // reproducible bit-for-bit.
        let f = cache_economics_sweep(6, 50, &cache_sweep_faults());
        assert_eq!(f.points.len(), 4);
        let restarts = f.points[0].fault_restarts;
        assert!(restarts > 0, "storm-tier sweep must fire restarts");
        for p in &f.points {
            assert_eq!(p.fault_restarts, restarts, "same crash schedule at {}", p.capacity);
            assert!(
                (0.0..=1.0).contains(&p.hit_rate) && (0.0..=1.0).contains(&p.shed_rate),
                "{}: rates out of range: hit {} shed {}",
                p.capacity,
                p.hit_rate,
                p.shed_rate
            );
        }
        for w in f.points.windows(2) {
            assert!(
                w[1].wasted_fraction < w[0].wasted_fraction,
                "knee must strictly fall: {} {} vs {} {}",
                w[0].capacity,
                w[0].wasted_fraction,
                w[1].capacity,
                w[1].wasted_fraction
            );
            assert!(
                w[1].evicted_bytes < w[0].evicted_bytes,
                "eviction pressure must strictly fall: {} {} vs {} {}",
                w[0].capacity,
                w[0].evicted_bytes,
                w[1].capacity,
                w[1].evicted_bytes
            );
            assert!(
                w[1].hit_rate >= w[0].hit_rate,
                "hit rate must not fall with capacity: {} {} vs {} {}",
                w[0].capacity,
                w[0].hit_rate,
                w[1].capacity,
                w[1].hit_rate
            );
        }
        let unbounded = f.point("unbounded");
        assert_eq!(unbounded.evicted_bytes, 0, "unbounded cache never evicts");
        assert!(f.point("3g").hit_rate < unbounded.hit_rate);
        assert!(!f.render().is_empty());
        let again = cache_economics_sweep(6, 50, &cache_sweep_faults());
        for (a, b) in f.points.iter().zip(again.points.iter()) {
            assert_eq!(a.wasted_fraction.to_bits(), b.wasted_fraction.to_bits());
            assert_eq!(a.evicted_bytes, b.evicted_bytes);
            assert_eq!(a.hit_rate.to_bits(), b.hit_rate.to_bits());
        }
    }

    #[test]
    fn artifact_sweep_strictly_reduces_bytes() {
        let f = artifact_sweep(1);
        assert_eq!(f.points.len(), 2);
        for p in &f.points {
            assert!(p.warm_bytes < p.cold_bytes, "nodes={}", p.nodes);
            assert!(p.delta_bytes < p.warm_bytes, "nodes={}", p.nodes);
            assert!(p.dedup_bytes < p.cold_bytes, "nodes={}", p.nodes);
            assert!(p.warm_s <= p.cold_s + 1e-9, "nodes={}", p.nodes);
            assert!(p.delta_s <= p.warm_s + 1e-9, "nodes={}", p.nodes);
            assert!(p.warm_bytes_fraction() < 1.0);
            assert!(p.delta_bytes_fraction() < p.warm_bytes_fraction());
        }
        assert!(!f.render().is_empty());
    }

    #[test]
    fn fragmentation_sweep_strictly_increases_and_reproduces() {
        let f = fragmentation_sweep(7);
        assert_eq!(f.points.len(), FRAG_SWEEP_RACKS.len());
        assert_eq!(f.points[0].cross_frac, 0.0, "one rack → no spine traffic");
        assert!((f.points.last().unwrap().cross_frac - 1.0).abs() < 1e-12);
        for w in f.points.windows(2) {
            assert!(w[1].cross_frac > w[0].cross_frac);
            assert!(
                w[1].worker_s > w[0].worker_s,
                "fragmentation must slow the warm startup: {} racks {} vs {} racks {}",
                w[0].racks_spanned,
                w[0].worker_s,
                w[1].racks_spanned,
                w[1].worker_s
            );
            assert!(w[1].total_s > w[0].total_s);
        }
        assert!(!f.render().is_empty());
        let again = fragmentation_sweep(7);
        for (a, b) in f.points.iter().zip(again.points.iter()) {
            assert_eq!(a.worker_s.to_bits(), b.worker_s.to_bits());
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
        }
    }

    #[test]
    fn fig14_spread_collapses() {
        let f = fig14(3);
        let b = BoxSummary::of(&f.baseline);
        let o = BoxSummary::of(&f.bootseer);
        assert!(o.max - o.min < (b.max - b.min) / 3.0);
        assert!(o.median < b.median / 3.0);
    }
}
