//! Closed-loop mitigation search over the BootSeer knob space
//! (ROADMAP item 5): instead of reporting what one configuration costs,
//! *derive* a recommendation — which combination of overlap mode,
//! prefetch budget, checkpoint cadence, dedup/delta, cache economics and
//! topology spends the fewest GPU-hours per byte of cache + prefetch
//! budget.
//!
//! The search is a deterministic seeded successive-halving ladder over a
//! declared [`KnobSpace`]:
//!
//!  1. **Screen rung** — the full Cartesian grid is evaluated at
//!     short-trace fidelity through [`crate::trace::batch_replay`], which
//!     shares one [`crate::trace::ReplayPrefix`] per distinct
//!     prefix-relevant knob setting (checkpoint cadence, racks) and one
//!     phase-2 evaluation per distinct effective config — the whole grid
//!     costs a few dozen phase-2 replays, not `|grid|` full replays.
//!  2. **Promotion** — candidates are ranked by screened wasted fraction
//!     (ties broken by declaration order) and the top
//!     [`OptimizeParams::survivors`] are promoted.
//!  3. **Full rung** — survivors re-replay at full-week fidelity, again
//!     batched, and the Pareto frontier of (cache + prefetch byte budget,
//!     wasted fraction) is extracted.
//!
//! Every step is a pure function of `(seed, space, fidelity)`: rankings
//! compare with `total_cmp` + index tie-breaks, the batched replay is
//! byte-identical at any thread count, and the report's JSON carries no
//! machine-dependent field — so the emitted frontier is reproducible
//! bit-for-bit across `--threads` (pinned by the tests below).
//!
//! See `docs/optimize.md` for the knob-space declaration, the fidelity
//! ladder, and the frontier format.

use crate::config::{BootseerConfig, CachePolicy, ClusterConfig, OverlapMode};
use crate::faults::FaultConfig;
use crate::trace::{batch_replay, gen_trace, ReplayOptions};
use crate::util::human;
use crate::util::json::Json;

/// The declared search space: one `Vec` per knob, the grid is the
/// Cartesian product in declaration order (outermost axis first). Axes
/// map one-to-one onto [`ReplayOptions`] setters, so a [`Candidate`] is
/// exactly one options value — there is no second configuration path.
#[derive(Clone, Debug)]
pub struct KnobSpace {
    /// Stage-graph overlap modes to try.
    pub overlap: Vec<OverlapMode>,
    /// Speculative prefetch budgets (bytes); only live under
    /// [`OverlapMode::Speculative`] — the batched engine collapses the
    /// dead combinations automatically.
    pub spec_prefetch_budget_bytes: Vec<u64>,
    /// Checkpoint cadences (seconds) — a fault-process knob, so each
    /// distinct value builds its own replay prefix.
    pub ckpt_interval_s: Vec<f64>,
    /// Cross-artifact chunk dedup on/off.
    pub dedup: Vec<bool>,
    /// Delta checkpoint resume on/off.
    pub delta_resume: Vec<bool>,
    /// Per-node warm-cache capacities (bytes, finite — the byte axis of
    /// the frontier).
    pub cache_capacity_bytes: Vec<u64>,
    /// Cache eviction policies.
    pub cache_policy: Vec<CachePolicy>,
    /// Topology rack counts (prefix-relevant).
    pub racks: Vec<u32>,
    /// Spine oversubscription factors (prefix-relevant).
    pub spine_oversub: Vec<f64>,
}

impl KnobSpace {
    /// The canonical search space: every mitigation axis the simulator
    /// exposes, at the operating points the paper's sweeps bracket.
    pub fn paper() -> KnobSpace {
        KnobSpace {
            overlap: vec![
                OverlapMode::Sequential,
                OverlapMode::Overlapped,
                OverlapMode::Speculative,
            ],
            spec_prefetch_budget_bytes: vec![2_000_000_000, 8_000_000_000],
            ckpt_interval_s: vec![1800.0, 3600.0],
            dedup: vec![false, true],
            delta_resume: vec![false, true],
            cache_capacity_bytes: vec![8_000_000_000, 24_000_000_000],
            cache_policy: vec![CachePolicy::Lru, CachePolicy::Gdsf],
            racks: vec![1, 4],
            spine_oversub: vec![1.0],
        }
    }

    /// A small space for tests and smoke runs: 12 candidates, one
    /// checkpoint cadence, one prefix.
    pub fn quick() -> KnobSpace {
        KnobSpace {
            overlap: vec![
                OverlapMode::Sequential,
                OverlapMode::Overlapped,
                OverlapMode::Speculative,
            ],
            spec_prefetch_budget_bytes: vec![2_000_000_000, 8_000_000_000],
            ckpt_interval_s: vec![3600.0],
            dedup: vec![false],
            delta_resume: vec![false, true],
            cache_capacity_bytes: vec![8_000_000_000],
            cache_policy: vec![CachePolicy::Lru],
            racks: vec![1],
            spine_oversub: vec![1.0],
        }
    }

    /// The full grid, in deterministic declaration order.
    pub fn candidates(&self) -> Vec<Candidate> {
        let mut out = Vec::new();
        for &overlap in &self.overlap {
            for &spec_prefetch_budget_bytes in &self.spec_prefetch_budget_bytes {
                for &ckpt_interval_s in &self.ckpt_interval_s {
                    for &dedup in &self.dedup {
                        for &delta_resume in &self.delta_resume {
                            for &cache_capacity_bytes in &self.cache_capacity_bytes {
                                for &cache_policy in &self.cache_policy {
                                    for &racks in &self.racks {
                                        for &spine_oversub in &self.spine_oversub {
                                            out.push(Candidate {
                                                overlap,
                                                spec_prefetch_budget_bytes,
                                                ckpt_interval_s,
                                                dedup,
                                                delta_resume,
                                                cache_capacity_bytes,
                                                cache_policy,
                                                racks,
                                                spine_oversub,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// One grid point: a concrete value per knob.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub overlap: OverlapMode,
    pub spec_prefetch_budget_bytes: u64,
    pub ckpt_interval_s: f64,
    pub dedup: bool,
    pub delta_resume: bool,
    pub cache_capacity_bytes: u64,
    pub cache_policy: CachePolicy,
    pub racks: u32,
    pub spine_oversub: f64,
}

impl Candidate {
    /// The candidate as replay options: the knobs fold into the builder,
    /// and the checkpoint cadence overrides the search's fault preset.
    /// This is the only candidate → replay path, for both rungs.
    pub fn options(&self, faults: &FaultConfig) -> ReplayOptions {
        let faults = FaultConfig { ckpt_interval_s: self.ckpt_interval_s, ..faults.clone() };
        ReplayOptions::new()
            .with_faults(faults)
            .with_overlap(self.overlap)
            .with_spec_prefetch_budget(self.spec_prefetch_budget_bytes)
            .with_dedup(self.dedup)
            .with_delta_resume(self.delta_resume)
            .with_cache(self.cache_capacity_bytes, self.cache_policy)
            .with_racks(self.racks)
            .with_spine_oversub(self.spine_oversub)
    }

    /// The frontier's byte axis: per-node cache capacity plus the
    /// speculative prefetch budget where it is actually spent
    /// (non-speculative modes never prefetch, so their budget costs
    /// nothing).
    pub fn byte_budget(&self) -> u64 {
        let spend = if self.overlap == OverlapMode::Speculative {
            self.spec_prefetch_budget_bytes
        } else {
            0
        };
        self.cache_capacity_bytes.saturating_add(spend)
    }

    /// Compact human label, one token per knob.
    pub fn label(&self) -> String {
        format!(
            "{} budget={} ckpt={:.0}s dedup={} delta={} cache={}/{} racks={} oversub={:.1}",
            self.overlap.name(),
            human::bytes(self.spec_prefetch_budget_bytes),
            self.ckpt_interval_s,
            self.dedup,
            self.delta_resume,
            human::bytes(self.cache_capacity_bytes),
            self.cache_policy.name(),
            self.racks,
            self.spine_oversub,
        )
    }
}

/// One rung of the fidelity ladder: how much synthetic trace a
/// candidate is evaluated against.
#[derive(Clone, Copy, Debug)]
pub struct Fidelity {
    /// Jobs in the synthetic trace.
    pub jobs: usize,
    /// Trace horizon (seconds).
    pub horizon_s: f64,
}

/// Everything a search run depends on. Two equal parameter sets produce
/// byte-identical reports at any thread count.
#[derive(Clone, Debug)]
pub struct OptimizeParams {
    /// Seed of both synthetic traces and every replay.
    pub seed: u64,
    /// Worker threads for the batched replays (0 → one per core);
    /// never affects the report's bytes.
    pub threads: usize,
    /// The declared knob space.
    pub space: KnobSpace,
    /// Short-trace screening rung (full grid).
    pub screen: Fidelity,
    /// Full-week rung (survivors only).
    pub full: Fidelity,
    /// Grid candidates promoted from the screen rung (clamped to the
    /// grid size).
    pub survivors: usize,
}

impl OptimizeParams {
    /// The canonical search: [`KnobSpace::paper`] screened on a 2-day /
    /// 24-job trace, 8 survivors promoted to the 50-job week.
    pub fn canonical(seed: u64, threads: usize) -> OptimizeParams {
        OptimizeParams {
            seed,
            threads,
            space: KnobSpace::paper(),
            screen: Fidelity { jobs: 24, horizon_s: 2.0 * 86400.0 },
            full: Fidelity { jobs: 50, horizon_s: 7.0 * 86400.0 },
            survivors: 8,
        }
    }

    /// Small parameters for tests and smoke runs: [`KnobSpace::quick`]
    /// screened on a 1-day / 10-job trace, 4 survivors promoted to a
    /// 2-day / 16-job trace.
    pub fn quick(seed: u64, threads: usize) -> OptimizeParams {
        OptimizeParams {
            seed,
            threads,
            space: KnobSpace::quick(),
            screen: Fidelity { jobs: 10, horizon_s: 86400.0 },
            full: Fidelity { jobs: 16, horizon_s: 2.0 * 86400.0 },
            survivors: 4,
        }
    }
}

/// Fault processes the search replays under: the cache-economics storm
/// tier (hot crash hazard, mostly same-node restarts), so warm-restart
/// knobs (cache capacity/policy, delta resume) have observable cost on
/// search-sized traces. The checkpoint cadence inside is overridden per
/// candidate.
pub fn optimize_faults() -> FaultConfig {
    FaultConfig { hazard_per_gpu_hour: 2.0e-3, relocate_prob: 0.2, ..FaultConfig::storm() }
}

/// One candidate's measurements across the ladder.
#[derive(Clone, Debug)]
pub struct CandidateOutcome {
    pub candidate: Candidate,
    /// Wasted fraction on the screen rung.
    pub screen_wasted_fraction: f64,
    /// Rank in the screen grid (0 = least waste).
    pub screen_rank: usize,
    /// Wasted fraction on the full rung (survivors only).
    pub full_wasted_fraction: Option<f64>,
    /// Startup GPU-hours on the full rung (survivors only).
    pub full_startup_gpu_hours: Option<f64>,
}

/// The search result: every candidate's outcomes, the promotion set,
/// and the Pareto frontier, plus the sharing telemetry of both rungs.
#[derive(Debug)]
pub struct OptimizeReport {
    pub seed: u64,
    pub screen: Fidelity,
    pub full: Fidelity,
    /// Per-candidate outcomes, in grid declaration order.
    pub outcomes: Vec<CandidateOutcome>,
    /// Candidate indices sorted by screened waste (ties by index).
    pub ranking: Vec<usize>,
    /// The promoted candidates: exactly the first
    /// [`OptimizeParams::survivors`] entries of `ranking`.
    pub survivors: Vec<usize>,
    /// Pareto frontier over the survivors, ordered by rising byte
    /// budget with strictly falling full-rung wasted fraction.
    pub frontier: Vec<usize>,
    /// Prefixes built / phase-2 evaluations run on the screen rung
    /// (the grid cost the batched engine actually paid).
    pub screen_prefix_builds: usize,
    pub screen_eval_groups: usize,
    pub full_prefix_builds: usize,
    pub full_eval_groups: usize,
}

/// Run the seeded successive-halving search. Deterministic: the report
/// (and its JSON) is byte-identical for equal parameters at any
/// `threads`.
pub fn run_optimize(params: &OptimizeParams) -> OptimizeReport {
    let cands = params.space.candidates();
    let cluster = ClusterConfig::default();
    let cfg = BootseerConfig::bootseer();
    let faults = optimize_faults();

    // Rung 1: full grid at screen fidelity, one batched evaluation.
    let screen_trace = gen_trace(params.seed, params.screen.jobs, params.screen.horizon_s);
    let opts: Vec<ReplayOptions> = cands.iter().map(|c| c.options(&faults)).collect();
    let screened = batch_replay(&screen_trace, &cluster, &cfg, params.seed, &opts, params.threads);
    let screen_wasted: Vec<f64> = screened.results.iter().map(|r| r.wasted_fraction()).collect();

    // Rank by screened waste; total_cmp + index keeps the order total
    // and deterministic (simulated fractions are never NaN).
    let mut ranking: Vec<usize> = (0..cands.len()).collect();
    ranking.sort_by(|&a, &b| screen_wasted[a].total_cmp(&screen_wasted[b]).then(a.cmp(&b)));
    let mut screen_rank = vec![0usize; cands.len()];
    for (rank, &i) in ranking.iter().enumerate() {
        screen_rank[i] = rank;
    }
    let k = if cands.is_empty() { 0 } else { params.survivors.clamp(1, cands.len()) };
    let survivors: Vec<usize> = ranking[..k].to_vec();

    // Rung 2: survivors at full fidelity, again batched.
    let full_trace = gen_trace(params.seed, params.full.jobs, params.full.horizon_s);
    let full_opts: Vec<ReplayOptions> =
        survivors.iter().map(|&i| cands[i].options(&faults)).collect();
    let finals = batch_replay(&full_trace, &cluster, &cfg, params.seed, &full_opts, params.threads);
    let mut full_wasted: Vec<Option<f64>> = vec![None; cands.len()];
    let mut full_startup: Vec<Option<f64>> = vec![None; cands.len()];
    for (s, r) in survivors.iter().zip(finals.results.iter()) {
        full_wasted[*s] = Some(r.wasted_fraction());
        full_startup[*s] = Some(r.startup_gpu_hours);
    }

    // Pareto frontier over the survivors: walk by rising byte budget
    // (ties by full-rung waste, then index) and keep every point that
    // strictly improves on the best waste so far.
    let mut by_budget = survivors.clone();
    by_budget.sort_by(|&a, &b| {
        let wa = full_wasted[a].unwrap_or(f64::INFINITY);
        let wb = full_wasted[b].unwrap_or(f64::INFINITY);
        cands[a].byte_budget().cmp(&cands[b].byte_budget()).then(wa.total_cmp(&wb)).then(a.cmp(&b))
    });
    let mut frontier = Vec::new();
    let mut best = f64::INFINITY;
    for &i in &by_budget {
        let w = full_wasted[i].unwrap_or(f64::INFINITY);
        if w < best {
            best = w;
            frontier.push(i);
        }
    }

    let outcomes = cands
        .into_iter()
        .enumerate()
        .map(|(i, candidate)| CandidateOutcome {
            candidate,
            screen_wasted_fraction: screen_wasted[i],
            screen_rank: screen_rank[i],
            full_wasted_fraction: full_wasted[i],
            full_startup_gpu_hours: full_startup[i],
        })
        .collect();
    OptimizeReport {
        seed: params.seed,
        screen: params.screen,
        full: params.full,
        outcomes,
        ranking,
        survivors,
        frontier,
        screen_prefix_builds: screened.prefix_builds,
        screen_eval_groups: screened.eval_groups,
        full_prefix_builds: finals.prefix_builds,
        full_eval_groups: finals.eval_groups,
    }
}

impl OptimizeReport {
    /// The frontier as (byte budget, full-rung wasted fraction, label)
    /// rows, rising budget / falling waste.
    pub fn frontier_points(&self) -> Vec<(u64, f64, String)> {
        self.frontier
            .iter()
            .map(|&i| {
                let o = &self.outcomes[i];
                (
                    o.candidate.byte_budget(),
                    o.full_wasted_fraction.unwrap_or(f64::INFINITY),
                    o.candidate.label(),
                )
            })
            .collect()
    }

    /// Least full-rung waste across the frontier (the recommendation's
    /// headline number).
    pub fn best_wasted_fraction(&self) -> f64 {
        self.frontier_points().iter().map(|p| p.1).fold(f64::INFINITY, f64::min)
    }

    /// Render the survivor table and the frontier.
    pub fn render(&self) -> String {
        let mut rows = vec![vec![
            "rank".to_string(),
            "candidate".to_string(),
            "screen wasted".to_string(),
            "week wasted".to_string(),
            "byte budget".to_string(),
            "frontier".to_string(),
        ]];
        for &i in &self.survivors {
            let o = &self.outcomes[i];
            rows.push(vec![
                o.screen_rank.to_string(),
                o.candidate.label(),
                format!("{:.3}%", 100.0 * o.screen_wasted_fraction),
                match o.full_wasted_fraction {
                    Some(w) => format!("{:.3}%", 100.0 * w),
                    None => "-".to_string(),
                },
                human::bytes(o.candidate.byte_budget()),
                if self.frontier.contains(&i) { "*".to_string() } else { String::new() },
            ]);
        }
        format!(
            "{}grid: {} candidates screened as {} prefix builds + {} evaluations; \
             {} survivors re-replayed as {} evaluations; frontier: {} points, best wasted {:.3}%\n",
            human::table(&rows),
            self.outcomes.len(),
            self.screen_prefix_builds,
            self.screen_eval_groups,
            self.survivors.len(),
            self.full_eval_groups,
            self.frontier.len(),
            100.0 * self.best_wasted_fraction(),
        )
    }

    /// Deterministic JSON export: no wall-clock or thread-count field,
    /// so equal searches serialize byte-identically.
    pub fn to_json(&self) -> Json {
        let cand_json = |i: usize| {
            let o = &self.outcomes[i];
            let c = &o.candidate;
            let mut j = Json::obj();
            j.set("label", c.label())
                .set("overlap", c.overlap.name())
                .set("spec_prefetch_budget_bytes", c.spec_prefetch_budget_bytes)
                .set("ckpt_interval_s", c.ckpt_interval_s)
                .set("dedup", c.dedup)
                .set("delta_resume", c.delta_resume)
                .set("cache_capacity_bytes", c.cache_capacity_bytes)
                .set("cache_policy", c.cache_policy.name())
                .set("racks", c.racks)
                .set("spine_oversub", c.spine_oversub)
                .set("byte_budget", c.byte_budget())
                .set("screen_wasted_fraction", o.screen_wasted_fraction)
                .set("screen_rank", o.screen_rank)
                .set("survivor", self.survivors.contains(&i))
                .set("frontier", self.frontier.contains(&i));
            if let Some(w) = o.full_wasted_fraction {
                j.set("full_wasted_fraction", w);
            }
            if let Some(h) = o.full_startup_gpu_hours {
                j.set("full_startup_gpu_hours", h);
            }
            j
        };
        let candidates: Vec<Json> = (0..self.outcomes.len()).map(cand_json).collect();
        let frontier: Vec<Json> = self
            .frontier_points()
            .into_iter()
            .map(|(budget, wasted, label)| {
                let mut j = Json::obj();
                j.set("byte_budget", budget).set("wasted_fraction", wasted).set("label", label);
                j
            })
            .collect();
        let mut j = Json::obj();
        j.set("seed", self.seed)
            .set("screen_jobs", self.screen.jobs)
            .set("screen_horizon_s", self.screen.horizon_s)
            .set("full_jobs", self.full.jobs)
            .set("full_horizon_s", self.full.horizon_s)
            .set("n_candidates", self.outcomes.len())
            .set("screen_prefix_builds", self.screen_prefix_builds)
            .set("screen_eval_groups", self.screen_eval_groups)
            .set("full_prefix_builds", self.full_prefix_builds)
            .set("full_eval_groups", self.full_eval_groups)
            .set("candidates", Json::Arr(candidates))
            .set("frontier", Json::Arr(frontier));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_declaration_ordered_and_complete() {
        let space = KnobSpace::quick();
        let cands = space.candidates();
        assert_eq!(cands.len(), 12);
        // Outermost axis varies slowest.
        assert_eq!(cands[0].overlap, OverlapMode::Sequential);
        assert_eq!(cands[4].overlap, OverlapMode::Overlapped);
        assert_eq!(cands[11].overlap, OverlapMode::Speculative);
        // Budget only spends under Speculative.
        assert_eq!(cands[0].byte_budget(), cands[0].cache_capacity_bytes);
        assert_eq!(
            cands[11].byte_budget(),
            cands[11].cache_capacity_bytes + cands[11].spec_prefetch_budget_bytes
        );
    }

    /// Satellite pin: same seed + knob space ⇒ byte-identical frontier
    /// JSON across thread counts, and the successive-halving survivors
    /// are a strict subset of the short-fidelity grid ranking — exactly
    /// its top-`survivors` prefix.
    #[test]
    fn optimize_is_deterministic_across_threads_and_survivors_follow_ranking() {
        let a = run_optimize(&OptimizeParams::quick(9, 1));
        let b = run_optimize(&OptimizeParams::quick(9, 4));
        assert_eq!(
            a.to_json().to_pretty(),
            b.to_json().to_pretty(),
            "frontier JSON must not depend on --threads"
        );
        // Strict subset of the grid, and exactly the ranking's head.
        assert!(a.survivors.len() < a.outcomes.len());
        assert_eq!(a.survivors, a.ranking[..a.survivors.len()].to_vec());
        let worst_promoted = a.survivors.iter().map(|&i| a.outcomes[i].screen_wasted_fraction);
        let best_dropped = a.ranking[a.survivors.len()..]
            .iter()
            .map(|&i| a.outcomes[i].screen_wasted_fraction)
            .fold(f64::INFINITY, f64::min);
        for w in worst_promoted {
            assert!(w <= best_dropped, "a dropped candidate out-screened a survivor");
        }
        // Ranking is the sorted order of the screen column.
        for w in a.ranking.windows(2) {
            assert!(
                a.outcomes[w[0]].screen_wasted_fraction
                    <= a.outcomes[w[1]].screen_wasted_fraction
            );
        }
    }

    #[test]
    fn frontier_is_pareto_and_survivor_only() {
        let r = run_optimize(&OptimizeParams::quick(9, 2));
        assert!(!r.frontier.is_empty(), "at least one frontier point");
        for &i in &r.frontier {
            assert!(r.survivors.contains(&i), "frontier is drawn from the survivors");
            assert!(r.outcomes[i].full_wasted_fraction.is_some());
        }
        let pts = r.frontier_points();
        for w in pts.windows(2) {
            assert!(w[0].0 <= w[1].0, "byte budget must rise along the frontier");
            assert!(w[1].1 < w[0].1, "waste must strictly fall along the frontier");
        }
        // No survivor dominates a frontier point (less-or-equal budget
        // and strictly less waste).
        for &f in &r.frontier {
            for &s in &r.survivors {
                let dominated = r.outcomes[s].candidate.byte_budget()
                    <= r.outcomes[f].candidate.byte_budget()
                    && r.outcomes[s].full_wasted_fraction.unwrap()
                        < r.outcomes[f].full_wasted_fraction.unwrap();
                assert!(!dominated, "survivor {s} dominates frontier point {f}");
            }
        }
        // Survivors replay under shared prefixes: the full rung never
        // builds more prefixes than it has survivors.
        assert!(r.full_prefix_builds <= r.survivors.len());
        assert!(r.full_eval_groups <= r.survivors.len());
        assert!(!r.render().is_empty());
    }

    #[test]
    fn report_json_parses_and_carries_the_frontier() {
        let r = run_optimize(&OptimizeParams::quick(3, 2));
        let text = r.to_json().to_pretty();
        assert!(crate::util::json::parse(&text).is_ok());
        assert!(text.contains("\"frontier\""));
        assert!(text.contains("\"screen_eval_groups\""));
    }
}
