//! Cross-module integration: full startup simulations feeding the profiler,
//! BootSeer vs baseline at the paper's scales, and real-bytes env-cache +
//! checkpoint paths composing with the sim (no artifacts required).

use bootseer::config::{BootseerConfig, ClusterConfig, JobConfig, OverlapMode};
use bootseer::env::cache::{pack, snapshot_dir, unpack, CacheCapture};
use bootseer::profiler::{LogParser, Stage, StageAnalysisService};
use bootseer::startup::{run_startup, StartupKind, World};
use bootseer::util::stats;

/// Fig 12 shape: BootSeer beats baseline ~2x at every paper scale.
#[test]
fn bootseer_vs_baseline_all_paper_scales() {
    for gpus in [16u32, 32, 48, 64, 128] {
        let job = JobConfig::paper_moe(gpus);
        let cluster = ClusterConfig::default();
        let mut wb = World::new();
        // Warm run records hot set + creates env cache.
        run_startup(
            1,
            0,
            &cluster,
            &job,
            &BootseerConfig::bootseer(),
            &mut wb,
            StartupKind::Full,
            3,
        );
        let boot = run_startup(
            1,
            1,
            &cluster,
            &job,
            &BootseerConfig::bootseer(),
            &mut wb,
            StartupKind::Full,
            4,
        );
        let mut w0 = World::new();
        let base = run_startup(
            1,
            0,
            &cluster,
            &job,
            &BootseerConfig::baseline(),
            &mut w0,
            StartupKind::Full,
            4,
        );
        let ratio = base.worker_phase_s / boot.worker_phase_s;
        assert!(
            (1.4..4.0).contains(&ratio),
            "gpus={gpus}: base {:.1}s boot {:.1}s ratio {ratio:.2}",
            base.worker_phase_s,
            boot.worker_phase_s
        );
    }
}

/// Stage-graph overlap modes at every paper scale: the ordering holds and
/// the profiler still round-trips the event stream cleanly.
#[test]
fn overlap_modes_ordered_at_all_paper_scales() {
    let cluster = ClusterConfig::default();
    for gpus in [16u32, 64, 128] {
        let job = JobConfig::paper_moe(gpus);
        let mut worker = Vec::new();
        for mode in OverlapMode::ALL {
            let cfg = BootseerConfig { overlap: mode, ..BootseerConfig::bootseer() };
            let mut w = World::new();
            run_startup(1, 0, &cluster, &job, &cfg, &mut w, StartupKind::Full, 3);
            let o = run_startup(1, 1, &cluster, &job, &cfg, &mut w, StartupKind::Full, 4);
            // Profiler ingests the overlapped stream without anomalies.
            let log: String = o.events.iter().map(|e| e.log_line() + "\n").collect();
            let mut svc = StageAnalysisService::new();
            svc.ingest_all(LogParser::parse_stream(&log));
            assert!(svc.anomalies.is_empty(), "gpus={gpus} mode={mode:?}");
            worker.push(o.worker_phase_s);
        }
        assert!(
            worker[1] <= worker[0] + 1e-9 && worker[2] <= worker[1] + 1e-9,
            "gpus={gpus}: seq/ovl/spec = {worker:?}"
        );
    }
}

/// The profiler round-trip at scale: log text -> parse -> durations match
/// the outcome's own accounting.
#[test]
fn profiler_roundtrip_matches_outcome() {
    let job = JobConfig::paper_moe(64);
    let mut w = World::new();
    let o = run_startup(
        9, 0, &ClusterConfig::default(), &job, &BootseerConfig::baseline(), &mut w,
        StartupKind::Full, 11,
    );
    let log: String = o.events.iter().map(|e| e.log_line() + "\n").collect();
    let mut svc = StageAnalysisService::new();
    svc.ingest_all(LogParser::parse_stream(&log));
    assert!(svc.anomalies.is_empty());
    let (b, e) = svc.db.job_stage_span(9, Stage::EnvSetup).unwrap();
    let span = o.span(Stage::EnvSetup).unwrap();
    assert!((b - span.0).abs() < 1e-6 && (e - span.1).abs() < 1e-6);
    // Install durations from the DB equal the outcome's.
    let mut from_db = svc.db.job_stage_durations(9, Stage::InstallScript);
    let mut from_outcome = o.install_durations.clone();
    from_db.sort_by(|a, b| a.partial_cmp(b).unwrap());
    from_outcome.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for (a, b) in from_db.iter().zip(&from_outcome) {
        assert!((a - b).abs() < 1e-6);
    }
}

/// Straggler elimination (Fig 14 shape) at the 128-GPU scale.
#[test]
fn env_cache_flattens_install_distribution() {
    let job = JobConfig::paper_moe(128);
    let cluster = ClusterConfig::default();
    let mut w = World::new();
    run_startup(1, 0, &cluster, &job, &BootseerConfig::bootseer(), &mut w, StartupKind::Full, 5);
    let hit = run_startup(
        1,
        1,
        &cluster,
        &job,
        &BootseerConfig::bootseer(),
        &mut w,
        StartupKind::Full,
        6,
    );
    let mut w0 = World::new();
    let base = run_startup(
        1,
        0,
        &cluster,
        &job,
        &BootseerConfig::baseline(),
        &mut w0,
        StartupKind::Full,
        6,
    );
    let spread_hit = stats::max(&hit.install_durations) - stats::min(&hit.install_durations);
    let spread_base = stats::max(&base.install_durations) - stats::min(&base.install_durations);
    assert!(spread_hit < spread_base / 3.0, "hit {spread_hit} base {spread_base}");
}

/// Real-bytes path: a fake site-packages dir, captured and restored on a
/// "replacement node", byte-identical.
#[test]
fn env_cache_real_bytes_roundtrip() {
    let root = std::env::temp_dir().join(format!("bs-int-env-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(root.join("site-packages")).unwrap();
    std::fs::write(root.join("site-packages/base.py"), b"# preinstalled").unwrap();

    let cap = CacheCapture::begin(&root).unwrap();
    // "pip install" effects:
    std::fs::create_dir_all(root.join("site-packages/nccl")).unwrap();
    std::fs::write(root.join("site-packages/nccl/__init__.py"), vec![b'x'; 50_000]).unwrap();
    std::fs::write(root.join("site-packages/base.py"), b"# patched").unwrap();
    let archive = cap.finish(3).unwrap();

    let replacement = std::env::temp_dir().join(format!("bs-int-env2-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&replacement);
    std::fs::create_dir_all(replacement.join("site-packages")).unwrap();
    std::fs::write(replacement.join("site-packages/base.py"), b"# preinstalled").unwrap();
    let restored = unpack(&archive, &replacement).unwrap();
    assert_eq!(restored.len(), 2);
    let a = snapshot_dir(&root).unwrap();
    let b = snapshot_dir(&replacement).unwrap();
    assert_eq!(a, b, "replacement node environment identical to node 0");
    std::fs::remove_dir_all(&root).unwrap();
    std::fs::remove_dir_all(&replacement).unwrap();
}

/// pack/unpack handles many small files (site-packages shape).
#[test]
fn env_cache_many_files() {
    let root = std::env::temp_dir().join(format!("bs-int-many-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let mut files = Vec::new();
    for i in 0..200 {
        let rel = std::path::PathBuf::from(format!("pkg{:02}/m{i}.py", i % 10));
        let abs = root.join(&rel);
        std::fs::create_dir_all(abs.parent().unwrap()).unwrap();
        std::fs::write(&abs, format!("# module {i}\n").repeat(i % 7 + 1)).unwrap();
        files.push(rel);
    }
    let archive = pack(&root, &files, 3).unwrap();
    let dest = std::env::temp_dir().join(format!("bs-int-many2-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dest);
    let restored = unpack(&archive, &dest).unwrap();
    assert_eq!(restored.len(), 200);
    assert_eq!(snapshot_dir(&root).unwrap(), snapshot_dir(&dest).unwrap());
    std::fs::remove_dir_all(&root).unwrap();
    std::fs::remove_dir_all(&dest).unwrap();
}
