//! End-to-end integration over the real artifacts: init → train steps with
//! decreasing loss → striped checkpoint save → resume → bit-identical
//! continuation. Requires `make artifacts` (skips politely otherwise).

use bootseer::hdfs::local::LocalStore;
use bootseer::trainer::{SyntheticCorpus, Trainer};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    d.join("meta.json").exists().then_some(d)
}

#[test]
fn train_checkpoint_resume_roundtrip() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let client = xla::PjRtClient::cpu().unwrap();
    let mut t = Trainer::new(&client, &dir, 42).unwrap();
    let (b, s) = (t.meta.batch, t.meta.seq);
    let mut corpus = SyntheticCorpus::new(t.meta.vocab, 0.05, 7);

    // A few steps must reduce loss from ~ln(V).
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let (tok, tgt) = corpus.batch(b, s);
        last = t.train_step(&tok, &tgt).unwrap();
        first.get_or_insert(last);
    }
    let first = first.unwrap();
    assert!(last < first, "loss should fall: {first} -> {last}");
    assert_eq!(t.loss_log.len(), 30);

    // Save striped, keep training 3 steps, then resume and replay the SAME
    // 3 batches: losses must match exactly (bit-identical params restored).
    let store_dir =
        std::env::temp_dir().join(format!("bootseer-train-int-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = LocalStore::open(&store_dir).unwrap();
    t.save(&store, "ckpt", 1_000_000, 4).unwrap();
    let fingerprint_at_save = t.param_fingerprint().unwrap();

    let replay_batches: Vec<_> = (0..3).map(|_| corpus.batch(b, s)).collect();
    let losses_a: Vec<f32> = replay_batches
        .iter()
        .map(|(tok, tgt)| t.train_step(tok, tgt).unwrap())
        .collect();

    // Resume via striped parallel read.
    t.resume(&store, "ckpt", true).unwrap();
    assert_eq!(t.param_fingerprint().unwrap(), fingerprint_at_save);
    assert_eq!(t.step, 30);
    let losses_b: Vec<f32> = replay_batches
        .iter()
        .map(|(tok, tgt)| t.train_step(tok, tgt).unwrap())
        .collect();
    assert_eq!(losses_a, losses_b, "resume must reproduce training exactly");

    // Baseline sequential read restores the same bytes.
    t.resume(&store, "ckpt", false).unwrap();
    assert_eq!(t.param_fingerprint().unwrap(), fingerprint_at_save);

    // Eval path works and is finite.
    let (tok, tgt) = corpus.batch(b, s);
    let ev = t.eval_loss(&tok, &tgt).unwrap();
    assert!(ev.is_finite());
    std::fs::remove_dir_all(&store_dir).unwrap();
}
