//! `bench-gate` — CI bench-regression gate.
//!
//! Usage:
//!
//!     bench-gate <baseline.json> <fresh.json> [--tol 0.35]
//!
//! Compares a fresh bench sweep (`BENCH_overlap.json`,
//! `BENCH_faults.json`) against the committed baseline under
//! `benches/baselines/`, failing (exit 1) on any tracked metric regressing
//! past the tolerance, or on schema drift between the two files. All
//! tracked metrics are lower-is-better; see `util::benchcmp` for the
//! rules. Improvements pass — regenerate the baseline from the fresh
//! artifact to ratchet them in.

use bootseer::util::benchcmp::compare;
use bootseer::util::diag;

const TOOL: &str = "bench-gate";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut tol = 0.35f64;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--tol" {
            tol = args
                .get(i + 1)
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| diag::usage_error(TOOL, "bad --tol value"));
            i += 2;
        } else {
            paths.push(args[i].clone());
            i += 1;
        }
    }
    if paths.len() != 2 {
        diag::usage_error(TOOL, "usage: bench-gate <baseline.json> <fresh.json> [--tol 0.35]");
    }
    let (base, fresh) = match (diag::load_json(&paths[0]), diag::load_json(&paths[1])) {
        (Ok(b), Ok(f)) => (b, f),
        (Err(e), _) | (_, Err(e)) => diag::usage_error(TOOL, &e),
    };
    let violations = compare(&base, &fresh, tol);
    if violations.is_empty() {
        println!(
            "bench-gate: {} within {:.0}% of {}",
            paths[1],
            100.0 * tol,
            paths[0]
        );
        return;
    }
    eprintln!(
        "bench-gate: {} regressed against {} ({} violation(s), tolerance {:.0}%):",
        paths[1],
        paths[0],
        violations.len(),
        100.0 * tol
    );
    for v in &violations {
        eprintln!("  {}: {}", v.path, v.detail);
    }
    eprintln!(
        "If this change is intentional, refresh the committed baseline from the fresh artifact."
    );
    std::process::exit(diag::EXIT_VIOLATIONS);
}
