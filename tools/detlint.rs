//! `detlint` — determinism & accounting static-analysis gate.
//!
//! Usage:
//!
//!     detlint [--root DIR] [--format human|json] [--output PATH] [--deny]
//!
//! Scans the repo's own Rust sources (`rust/src`, `tools`, `benches`,
//! `examples`) with the rule set in `bootseer::analysis` and reports
//! findings. Exit codes follow the shared gate contract (`util::diag`):
//! 0 clean, 1 unsuppressed findings, 2 usage/I/O error. `--deny` is the
//! default behavior and is accepted explicitly so the CI invocation reads
//! as what it is; `--warn` downgrades findings to a report-only run.
//!
//! `--output PATH` additionally writes the JSON report to a file (the CI
//! artifact) regardless of the terminal `--format`.
//!
//! Rule catalog, suppression syntax, and the JSON schema: `docs/detlint.md`.

use bootseer::analysis::run_tree;
use bootseer::util::diag;
use std::path::Path;

const TOOL: &str = "detlint";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root = ".".to_string();
    let mut format = "human".to_string();
    let mut output: Option<String> = None;
    let mut deny = true;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" | "--format" | "--output" => {
                let Some(val) = args.get(i + 1) else {
                    diag::usage_error(TOOL, &format!("{} needs a value", args[i]));
                };
                match args[i].as_str() {
                    "--root" => root = val.clone(),
                    "--format" => format = val.clone(),
                    _ => output = Some(val.clone()),
                }
                i += 2;
            }
            "--deny" => {
                deny = true;
                i += 1;
            }
            "--warn" => {
                deny = false;
                i += 1;
            }
            other => diag::usage_error(
                TOOL,
                &format!(
                    "unknown argument `{other}` \
                     (usage: detlint [--root DIR] [--format human|json] [--output PATH] [--deny])"
                ),
            ),
        }
    }
    if format != "human" && format != "json" {
        diag::usage_error(TOOL, &format!("--format must be human or json, got `{format}`"));
    }
    let report = match run_tree(Path::new(&root)) {
        Ok(r) => r,
        Err(e) => diag::usage_error(TOOL, &format!("scanning {root}: {e}")),
    };
    if let Some(path) = &output {
        diag::write_or_exit(TOOL, path, &report.to_json().to_pretty());
    }
    if format == "json" {
        println!("{}", report.to_json().to_pretty());
    } else {
        print!("{}", report.render_human());
    }
    if deny && report.unsuppressed_count() > 0 {
        std::process::exit(diag::EXIT_VIOLATIONS);
    }
}
