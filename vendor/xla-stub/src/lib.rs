//! API-surface stub of the PJRT `xla` bindings.
//!
//! The real crate wraps libxla/PJRT and cannot live in the offline crate
//! universe, but the feature-gated `runtime`/`trainer` code must not rot
//! unbuilt: this stub mirrors exactly the API surface those modules use,
//! so `cargo check --features pjrt` type-checks them in CI. Every function
//! panics at runtime — to actually train, vendor the real bindings at this
//! path (the `Cargo.toml` dependency line stays the same).

use std::borrow::Borrow;

/// Error type of the bindings (only ever formatted with `{:?}` upstream).
#[derive(Debug)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} — vendor the real PJRT bindings at vendor/xla-stub to run this"
    )))
}

/// Scalar element types the bindings accept (the subset bootseer uses).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (tensor) value.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal(())
    }

    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        stub("Literal::get_first_element")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Deserialized HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A PJRT client (CPU backend in bootseer's usage).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }
}

/// A compiled executable loaded on a client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer produced by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_explanatory() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("vendor the real PJRT bindings"));
    }

    #[test]
    fn literal_construction_is_inert() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert_eq!(l.element_count(), 0);
        assert!(l.reshape(&[2]).is_err());
    }
}
