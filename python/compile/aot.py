"""AOT lowering: JAX → HLO *text* artifacts the Rust runtime loads.

Emits, for a chosen model preset:
  artifacts/train_step.hlo.txt   (loss, *new_params) = f(*params, tok, tgt)
  artifacts/init.hlo.txt         (*params,)          = f(seed)
  artifacts/eval.hlo.txt         (loss,)             = f(*params, tok, tgt)
  artifacts/meta.json            param names/shapes, config, input layout

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.model import ModelConfig, init_fn, param_order, train_step, eval_loss, n_params


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the text
    parser on the Rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(cfg: ModelConfig, outdir: str, train_path: str | None = None):
    os.makedirs(outdir, exist_ok=True)
    order = param_order(cfg)
    p_specs = tuple(
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in order
    )
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

    # train_step(*params, tokens, targets) -> (loss, *params)
    def ts(*args):
        params = args[: len(order)]
        tokens, targets = args[len(order)], args[len(order) + 1]
        return train_step(cfg, params, tokens, targets)

    lowered = jax.jit(ts).lower(*p_specs, tok, tok)
    train_file = train_path or os.path.join(outdir, "train_step.hlo.txt")
    with open(train_file, "w") as f:
        f.write(to_hlo_text(lowered))

    # init(seed) -> (*params,)
    def init(seed):
        return init_fn(cfg, seed)

    lowered_init = jax.jit(init).lower(jax.ShapeDtypeStruct((), jnp.int32))
    with open(os.path.join(outdir, "init.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_init))

    # eval(*params, tokens, targets) -> (loss,)
    def ev(*args):
        params = args[: len(order)]
        return eval_loss(cfg, params, args[len(order)], args[len(order) + 1])

    lowered_eval = jax.jit(ev).lower(*p_specs, tok, tok)
    with open(os.path.join(outdir, "eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_eval))

    meta = {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "n_experts": cfg.n_experts,
            "batch": cfg.batch,
            "seq": cfg.seq,
            "lr": cfg.lr,
        },
        "n_params": int(n_params(cfg)),
        "params": [{"name": n, "shape": list(s)} for n, s in order],
        "inputs": ["*params", "tokens:i32[batch,seq]", "targets:i32[batch,seq]"],
        "train_outputs": ["loss:f32[]", "*params"],
    }
    with open(os.path.join(outdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/train_step.hlo.txt",
                    help="path of the train-step HLO artifact; siblings land next to it")
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "small", "large", "paper"])
    args = ap.parse_args()
    cfg = ModelConfig().scaled(args.preset)
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    meta = lower_all(cfg, outdir, train_path=os.path.abspath(args.out))
    print(
        f"AOT: preset={args.preset} params={meta['n_params']:,} "
        f"→ {outdir}/{{train_step,init,eval}}.hlo.txt + meta.json"
    )


if __name__ == "__main__":
    main()
