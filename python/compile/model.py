"""L2: the MoE transformer training step in JAX (build-time only).

The paper's experimental workload is an 8-layer, 128-expert MoE model
(§5.1). This module implements that architecture (scaled to the CPU test
machine by default), with the expert FFN computed by the L1 Pallas kernel
(`kernels.moe.moe_ffn`). `aot.py` lowers `init_fn` and `train_step` to HLO
text; the Rust trainer executes them over PJRT — Python never runs on the
training path.

Parameters travel as a flat, ordered list of f32 arrays (`PARAM_ORDER`)
so the Rust side can marshal literals and checkpoints without a pytree
library.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.moe import moe_ffn


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256       # per-expert hidden
    n_experts: int = 4
    batch: int = 4
    seq: int = 32
    lr: float = 0.5

    @property
    def tokens(self):
        return self.batch * self.seq

    def scaled(self, name):
        """Named presets: tiny (default), small (~13M), paper-shape
        (8 layers x 128 experts, for AOT-structure checks only)."""
        presets = {
            "tiny": ModelConfig(),
            "small": ModelConfig(vocab=2048, d_model=256, n_layers=4,
                                 n_heads=8, d_ff=512, n_experts=8,
                                 batch=8, seq=64),
            "large": ModelConfig(vocab=8192, d_model=512, n_layers=8,
                                 n_heads=8, d_ff=1024, n_experts=16,
                                 batch=8, seq=128),
            "paper": ModelConfig(vocab=8192, d_model=512, n_layers=8,
                                 n_heads=8, d_ff=1024, n_experts=128,
                                 batch=4, seq=64),
        }
        return presets[name]


def param_order(cfg: ModelConfig):
    """Names + shapes of every parameter, in wire order."""
    out = [("embed", (cfg.vocab, cfg.d_model))]
    for l in range(cfg.n_layers):
        out += [
            (f"l{l}.wq", (cfg.d_model, cfg.d_model)),
            (f"l{l}.wk", (cfg.d_model, cfg.d_model)),
            (f"l{l}.wv", (cfg.d_model, cfg.d_model)),
            (f"l{l}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{l}.gate", (cfg.d_model, cfg.n_experts)),
            (f"l{l}.w1", (cfg.n_experts, cfg.d_model, cfg.d_ff)),
            (f"l{l}.w2", (cfg.n_experts, cfg.d_ff, cfg.d_model)),
            (f"l{l}.ln1", (cfg.d_model,)),
            (f"l{l}.ln2", (cfg.d_model,)),
        ]
    out.append(("head", (cfg.d_model, cfg.vocab)))
    return out


def n_params(cfg: ModelConfig):
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_order(cfg))


def init_fn(cfg: ModelConfig, seed):
    """Initialize parameters from an i32 seed. Returns the flat tuple."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_order(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(float(fan_in))
            )
    return tuple(params)


def _rmsnorm(x, gain):
    return x * gain / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _attention(cfg, x, wq, wk, wv, wo):
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    q = (x @ wq).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask, logits, -1e30)
    att = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    return out.transpose(0, 2, 1, 3).reshape(B, S, D) @ wo


def _moe_layer(cfg, x, gate_w, w1, w2):
    """Top-1 (switch) routing with full capacity, dense dispatch, expert FFN
    via the Pallas kernel."""
    B, S, D = x.shape
    T = B * S
    E = cfg.n_experts
    xt = x.reshape(T, D)
    gate_logits = xt @ gate_w                       # [T, E]
    gate_p = jax.nn.softmax(gate_logits, axis=-1)
    top = jnp.argmax(gate_logits, axis=-1)          # [T]
    dispatch = jax.nn.one_hot(top, E, dtype=xt.dtype)  # [T, E]
    # Expert-major capacity layout: capacity C = T (no token dropping).
    xe = jnp.einsum("te,td->etd", dispatch, xt)     # [E, T, D]
    ye = moe_ffn(xe, w1, w2)                        # [E, T, D]  (L1 kernel)
    # Combine, scaled by the router probability of the chosen expert.
    chosen_p = jnp.sum(gate_p * dispatch, axis=-1, keepdims=True)  # [T, 1]
    yt = jnp.einsum("etd,te->td", ye, dispatch) * chosen_p
    return yt.reshape(B, S, D)


def forward(cfg: ModelConfig, params, tokens):
    """Logits for an i32 [B, S] token batch."""
    it = iter(params)
    embed = next(it)
    x = embed[tokens]  # [B, S, D]
    for _ in range(cfg.n_layers):
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        gate, w1, w2 = next(it), next(it), next(it)
        ln1, ln2 = next(it), next(it)
        x = x + _attention(cfg, _rmsnorm(x, ln1), wq, wk, wv, wo)
        x = x + _moe_layer(cfg, _rmsnorm(x, ln2), gate, w1, w2)
    head = next(it)
    return x @ head


def loss_fn(cfg: ModelConfig, params, tokens, targets):
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, params, tokens, targets):
    """One SGD step. Returns (loss, *new_params) — a flat tuple so the HLO
    output is a plain tuple the Rust runtime unpacks positionally."""
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, targets)
    )(tuple(params))
    new_params = tuple(p - cfg.lr * g for p, g in zip(params, grads))
    return (loss,) + new_params


def eval_loss(cfg: ModelConfig, params, tokens, targets):
    """Loss only (for held-out evaluation from Rust)."""
    return (loss_fn(cfg, params, tokens, targets),)
