"""Pure-jnp oracle for the Pallas MoE kernels — the correctness reference
every kernel test compares against (build-time only, never shipped)."""

import jax.numpy as jnp


def moe_ffn_ref(xe, w1, w2):
    """Grouped expert FFN, pure einsum: relu(xe @ w1) @ w2 per expert."""
    h = jnp.maximum(jnp.einsum("ecd,edf->ecf", xe, w1), 0.0)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def moe_ffn_ref_grads(xe, w1, w2, g):
    """Hand-derived backward of `moe_ffn_ref` (for vjp tests)."""
    h_pre = jnp.einsum("ecd,edf->ecf", xe, w1)
    h = jnp.maximum(h_pre, 0.0)
    dh = jnp.einsum("ecd,efd->ecf", g, w2) * (h_pre > 0.0).astype(g.dtype)
    dx = jnp.einsum("ecf,edf->ecd", dh, w1)
    dw1 = jnp.einsum("ecd,ecf->edf", xe, dh)
    dw2 = jnp.einsum("ecf,ecd->efd", h, g)
    return dx, dw1, dw2
