"""L1: Pallas kernels for the MoE expert FFN — the compute hot-spot of the
paper's experimental workload (an 8-layer, 128-expert MoE model, §5.1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's workload
ran on H800s where expert FFNs are scatter + batched GEMMs over warps. On
TPU we re-express the insight as a *dense, capacity-bucketed grouped
matmul*: routing produces a static [E, C, D] expert-major layout so the
HBM↔VMEM schedule is fully static; the Pallas grid iterates experts, each
step staging one expert's token block and weight tiles into VMEM and
driving MXU-shaped matmuls. `interpret=True` everywhere — the CPU PJRT
client cannot execute Mosaic custom-calls; structure, not wallclock, is
what the TPU story rests on (see EXPERIMENTS.md §Perf L1).

Because `jax.grad` cannot differentiate through `pallas_call`, the FFN is
wrapped in a `jax.custom_vjp` whose forward AND backward are Pallas
kernels. The backward recomputes the hidden activations in-kernel
(rematerialization: costs one extra matmul, saves [E, C, F] of VMEM/HBM
residual traffic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls (see module doc).


def _expert_specs(C, D, F):
    """BlockSpecs staging one expert per grid step into VMEM."""
    return dict(
        xe=pl.BlockSpec((1, C, D), lambda e: (e, 0, 0)),
        w1=pl.BlockSpec((1, D, F), lambda e: (e, 0, 0)),
        w2=pl.BlockSpec((1, F, D), lambda e: (e, 0, 0)),
    )


def _fwd_call(xe, w1, w2):
    E, C, D = xe.shape
    F = w1.shape[2]
    spec = _expert_specs(C, D, F)

    def kernel(xe_ref, w1_ref, w2_ref, out_ref):
        # Leading singleton expert dim from the BlockSpec.
        x = xe_ref[0]
        h = jnp.maximum(x @ w1_ref[0], 0.0)
        out_ref[0] = (h @ w2_ref[0]).astype(out_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(E,),
        in_specs=[spec["xe"], spec["w1"], spec["w2"]],
        out_specs=pl.BlockSpec((1, C, D), lambda e: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), xe.dtype),
        interpret=INTERPRET,
    )(xe, w1, w2)


def _bwd_call(xe, w1, w2, g):
    E, C, D = xe.shape
    F = w1.shape[2]
    spec = _expert_specs(C, D, F)

    def kernel(xe_ref, w1_ref, w2_ref, g_ref, dx_ref, dw1_ref, dw2_ref):
        x = xe_ref[0]
        w1b = w1_ref[0]
        h = jnp.maximum(x @ w1b, 0.0)  # remat
        gb = g_ref[0]
        dh = (gb @ w2_ref[0].T) * (h > 0.0).astype(gb.dtype)
        dx_ref[0] = (dh @ w1b.T).astype(dx_ref.dtype)
        dw1_ref[0] = (x.T @ dh).astype(dw1_ref.dtype)
        dw2_ref[0] = (h.T @ gb).astype(dw2_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(E,),
        in_specs=[spec["xe"], spec["w1"], spec["w2"],
                  pl.BlockSpec((1, C, D), lambda e: (e, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, C, D), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, D, F), lambda e: (e, 0, 0)),
            pl.BlockSpec((1, F, D), lambda e: (e, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, C, D), xe.dtype),
            jax.ShapeDtypeStruct((E, D, F), xe.dtype),
            jax.ShapeDtypeStruct((E, F, D), xe.dtype),
        ],
        interpret=INTERPRET,
    )(xe, w1, w2, g)


@jax.custom_vjp
def moe_ffn(xe, w1, w2):
    """Grouped expert FFN: per expert e, relu(xe[e] @ w1[e]) @ w2[e].

    xe: [E, C, D] capacity-bucketed expert inputs
    w1: [E, D, F], w2: [E, F, D]
    returns [E, C, D]
    """
    return _fwd_call(xe, w1, w2)


def _moe_ffn_fwd(xe, w1, w2):
    return _fwd_call(xe, w1, w2), (xe, w1, w2)


def _moe_ffn_bwd(res, g):
    xe, w1, w2 = res
    return _bwd_call(xe, w1, w2, g)


moe_ffn.defvjp(_moe_ffn_fwd, _moe_ffn_bwd)


@functools.lru_cache(maxsize=None)
def vmem_footprint_bytes(E, C, D, F, dtype_bytes=4):
    """Estimated VMEM working set of one forward grid step (DESIGN.md §Perf):
    xe block + w1 + w2 + h scratch + out block."""
    return dtype_bytes * (C * D + D * F + F * D + C * F + C * D)


def mxu_utilization_estimate(C, D, F, tile=128):
    """Fraction of MXU lanes busy for the expert matmuls given padding to
    `tile` (TPU systolic array is tile x tile)."""
    def eff(m, k, n):
        pad = lambda x: ((x + tile - 1) // tile) * tile
        return (m * k * n) / (pad(m) * pad(k) * pad(n))

    # Two matmuls: [C,D]@[D,F] and [C,F]@[F,D].
    return 0.5 * (eff(C, D, F) + eff(C, F, D))
