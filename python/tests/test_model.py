"""L2 correctness: model shapes, determinism, and trainability; plus the
AOT lowering contract (HLO text parses, meta matches)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    ModelConfig,
    eval_loss,
    forward,
    init_fn,
    loss_fn,
    n_params,
    param_order,
    train_step,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig()  # tiny preset


def data(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(k, (cfg.batch, cfg.seq), 0, cfg.vocab)
    # Learnable synthetic structure: next token = (7t + 3) mod V.
    targets = (tokens * 7 + 3) % cfg.vocab
    return tokens, targets


class TestModel:
    def test_param_order_covers_n_params(self):
        params = init_fn(CFG, 0)
        assert len(params) == len(param_order(CFG))
        total = sum(int(np.prod(p.shape)) for p in params)
        assert total == n_params(CFG)

    def test_init_deterministic(self):
        a = init_fn(CFG, 42)
        b = init_fn(CFG, 42)
        c = init_fn(CFG, 43)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        assert any(not np.array_equal(x, z) for x, z in zip(a, c))

    def test_forward_shape(self):
        params = init_fn(CFG, 0)
        tokens, _ = data(CFG)
        logits = forward(CFG, params, tokens)
        assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
        assert np.all(np.isfinite(logits))

    def test_loss_near_uniform_at_init(self):
        params = init_fn(CFG, 0)
        tokens, targets = data(CFG)
        loss = float(loss_fn(CFG, params, tokens, targets))
        uniform = np.log(CFG.vocab)
        assert abs(loss - uniform) < 1.5, f"init loss {loss} vs uniform {uniform}"

    def test_train_step_shapes_and_loss_output(self):
        params = init_fn(CFG, 0)
        tokens, targets = data(CFG)
        out = train_step(CFG, params, tokens, targets)
        assert len(out) == 1 + len(params)
        assert out[0].shape == ()
        for p, q in zip(params, out[1:]):
            assert p.shape == q.shape

    def test_loss_decreases_over_steps(self):
        # The e2e training claim, in miniature: 30 steps on the synthetic
        # next-token rule must cut the loss meaningfully.
        params = init_fn(CFG, 0)
        step = jax.jit(lambda ps, tok, tgt: train_step(CFG, ps, tok, tgt))
        first = None
        for i in range(30):
            tokens, targets = data(CFG, seed=i)
            out = step(tuple(params), tokens, targets)
            loss, params = float(out[0]), out[1:]
            if first is None:
                first = loss
        assert loss < first * 0.9, f"loss {first} → {loss}"

    def test_eval_matches_loss(self):
        params = init_fn(CFG, 0)
        tokens, targets = data(CFG)
        (ev,) = eval_loss(CFG, params, tokens, targets)
        assert abs(float(ev) - float(loss_fn(CFG, params, tokens, targets))) < 1e-6

    def test_presets_scale(self):
        tiny = n_params(ModelConfig().scaled("tiny"))
        small = n_params(ModelConfig().scaled("small"))
        large = n_params(ModelConfig().scaled("large"))
        assert tiny < small < large
        assert large > 50_000_000, f"large preset {large} params"


class TestAot:
    def test_lower_all_emits_parseable_artifacts(self, tmp_path):
        from compile.aot import lower_all

        meta = lower_all(CFG, str(tmp_path))
        for f in ["train_step.hlo.txt", "init.hlo.txt", "eval.hlo.txt", "meta.json"]:
            p = tmp_path / f
            assert p.exists() and p.stat().st_size > 0, f
        text = (tmp_path / "train_step.hlo.txt").read_text()
        assert text.startswith("HloModule"), text[:40]
        # The MoE grouped matmuls must appear in the lowered module.
        assert "dot(" in text
        m = json.loads((tmp_path / "meta.json").read_text())
        assert m["n_params"] == n_params(CFG)
        assert len(m["params"]) == len(param_order(CFG))
        assert meta["config"]["n_experts"] == CFG.n_experts

    def test_artifact_executes_in_jax(self, tmp_path):
        # Round-trip sanity: the lowered train step, when compiled by this
        # process's own XLA from the same jitted fn, reproduces eager.
        params = init_fn(CFG, 0)
        tokens, targets = data(CFG)
        eager = train_step(CFG, params, tokens, targets)
        jitted = jax.jit(lambda *a: train_step(CFG, a[: len(params)], a[-2], a[-1]))
        out = jitted(*params, tokens, targets)
        np.testing.assert_allclose(float(out[0]), float(eager[0]), rtol=1e-5)
