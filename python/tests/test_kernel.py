"""L1 correctness: Pallas MoE kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; fixed cases pin the paper-shaped
configuration. These are the CORE correctness signal for the kernel that
ends up inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.moe import moe_ffn, vmem_footprint_bytes, mxu_utilization_estimate
from compile.kernels.ref import moe_ffn_ref, moe_ffn_ref_grads

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


def make_inputs(E, C, D, F, seed=0, dtype=jnp.float32):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    return (
        rand(k1, (E, C, D), dtype),
        rand(k2, (E, D, F), dtype) / np.sqrt(D),
        rand(k3, (E, F, D), dtype) / np.sqrt(F),
    )


class TestForward:
    def test_matches_ref_paper_shape(self):
        xe, w1, w2 = make_inputs(8, 64, 32, 64)
        np.testing.assert_allclose(
            moe_ffn(xe, w1, w2), moe_ffn_ref(xe, w1, w2), rtol=1e-5, atol=1e-5
        )

    def test_single_expert(self):
        xe, w1, w2 = make_inputs(1, 16, 8, 8)
        np.testing.assert_allclose(
            moe_ffn(xe, w1, w2), moe_ffn_ref(xe, w1, w2), rtol=1e-5, atol=1e-5
        )

    def test_zero_input_gives_zero(self):
        xe, w1, w2 = make_inputs(4, 8, 8, 16)
        out = moe_ffn(jnp.zeros_like(xe), w1, w2)
        assert np.allclose(out, 0.0)

    def test_relu_kills_negative_branch(self):
        # With strongly negative w1 and positive x, h==0 → output 0.
        xe = jnp.ones((2, 4, 4))
        w1 = -jnp.ones((2, 4, 8))
        w2 = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4))
        assert np.allclose(moe_ffn(xe, w1, w2), 0.0)

    @settings(max_examples=25, deadline=None)
    @given(
        E=st.integers(1, 6),
        C=st.integers(1, 24),
        D=st.integers(1, 24),
        F=st.integers(1, 24),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_hypothesis(self, E, C, D, F, seed):
        xe, w1, w2 = make_inputs(E, C, D, F, seed)
        np.testing.assert_allclose(
            moe_ffn(xe, w1, w2), moe_ffn_ref(xe, w1, w2), rtol=2e-4, atol=2e-4
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        xe, w1, w2 = make_inputs(2, 8, 8, 8, dtype=dtype)
        out = moe_ffn(xe, w1, w2)
        ref = moe_ffn_ref(xe, w1, w2)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
        )


class TestBackward:
    def test_vjp_matches_hand_derived(self):
        xe, w1, w2 = make_inputs(3, 8, 6, 10, seed=7)
        g = jax.random.normal(jax.random.PRNGKey(9), xe.shape)
        _, vjp = jax.vjp(moe_ffn, xe, w1, w2)
        dx, dw1, dw2 = vjp(g)
        rx, rw1, rw2 = moe_ffn_ref_grads(xe, w1, w2, g)
        np.testing.assert_allclose(dx, rx, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dw1, rw1, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(dw2, rw2, rtol=1e-4, atol=1e-5)

    def test_grad_matches_ref_autodiff(self):
        xe, w1, w2 = make_inputs(2, 6, 4, 8, seed=3)

        def loss_kernel(w1, w2):
            return jnp.sum(moe_ffn(xe, w1, w2) ** 2)

        def loss_ref(w1, w2):
            return jnp.sum(moe_ffn_ref(xe, w1, w2) ** 2)

        gk = jax.grad(loss_kernel, argnums=(0, 1))(w1, w2)
        gr = jax.grad(loss_ref, argnums=(0, 1))(w1, w2)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        E=st.integers(1, 4),
        C=st.integers(1, 12),
        D=st.integers(1, 12),
        F=st.integers(1, 12),
        seed=st.integers(0, 2**16),
    )
    def test_vjp_hypothesis(self, E, C, D, F, seed):
        xe, w1, w2 = make_inputs(E, C, D, F, seed)
        g = jax.random.normal(jax.random.PRNGKey(seed + 1), xe.shape)
        _, vjp = jax.vjp(moe_ffn, xe, w1, w2)
        outs = vjp(g)
        refs = moe_ffn_ref_grads(xe, w1, w2, g)
        for a, b in zip(outs, refs):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)

    def test_jittable(self):
        xe, w1, w2 = make_inputs(2, 8, 8, 8)

        @jax.jit
        def f(xe, w1, w2):
            return jnp.sum(moe_ffn(xe, w1, w2))

        assert np.isfinite(float(f(xe, w1, w2)))


class TestPerfModel:
    def test_vmem_footprint_formula(self):
        # xe + w1 + w2 + h + out, f32.
        assert vmem_footprint_bytes(8, 64, 32, 64) == 4 * (
            64 * 32 + 32 * 64 + 64 * 32 + 64 * 64 + 64 * 32
        )

    def test_vmem_fits_16mb_for_paper_tile(self):
        # DESIGN.md §Perf target: one grid step ≤ 16 MB VMEM.
        assert vmem_footprint_bytes(128, 512, 512, 1024) <= 16 * 2**20

    def test_mxu_estimate_bounds(self):
        u = mxu_utilization_estimate(512, 512, 1024)
        assert u == 1.0  # perfectly tiled
        u2 = mxu_utilization_estimate(100, 100, 100)
        assert 0.0 < u2 < 1.0
