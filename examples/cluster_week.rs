//! Cluster-week replay: synthesizes the paper's §3 production week
//! (scaled), replays every startup of every job through the pipeline
//! simulator + profiler, prints Figures 1/3/4/5 data, and runs the
//! scheduler substrate over the same trace for queue-wait statistics.
//!
//!     cargo run --release --example cluster_week
//!     BOOTSEER_TRACE_JOBS=2800 cargo run --release --example cluster_week

use bootseer::figures;
use bootseer::scheduler::{schedule, SchedJob};
use bootseer::trace::gen_trace;
use bootseer::util::{human, stats};

fn main() {
    let n_jobs = figures::default_trace_jobs();
    println!("synthesizing a cluster week: {n_jobs} jobs (paper: 28,000+; scale with BOOTSEER_TRACE_JOBS)\n");

    let r = figures::week_replay(1);
    println!("-- Fig 1: GPU-hours split --\n{}", figures::fig01(&r).render());
    println!("-- Fig 3a/3b: startup overhead vs scale --\n{}", figures::fig03(&r).render());
    println!("-- Fig 4: startups per job --\n{}", figures::fig04(&r).render());
    println!("-- Fig 5: stage breakdown --\n{}", figures::fig05(&r).render());

    // Scheduler substrate: what queue waits would this load induce on a
    // finite pool? (The pipeline sim samples queue waits from the §3.2
    // distribution; this independently derives them from contention.)
    let trace = gen_trace(1, n_jobs, 7.0 * 86400.0);
    let jobs: Vec<SchedJob> = r
        .jobs
        .iter()
        .zip(&trace)
        .map(|(jr, tj)| SchedJob {
            id: tj.id,
            submit_s: tj.submit_s,
            gpus: tj.gpus,
            hold_s: tj.train_hours * 3600.0 + jr.startup_worker_s.iter().sum::<f64>(),
            priority: tj.priority,
        })
        .collect();
    let pool: u32 = 70_000; // the paper's week requested >700k GPUs across 28k jobs
    let outcomes = schedule(pool, &jobs);
    let waits: Vec<f64> = outcomes.iter().map(|o| o.queue_wait_s).collect();
    println!("-- scheduler: queue waits on a {pool}-GPU pool --");
    println!(
        "median {}  p90 {}  max {}",
        human::secs(stats::median(&waits)),
        human::secs(stats::quantile(&waits, 0.9)),
        human::secs(stats::max(&waits)),
    );
}
