//! Cluster-week replay: synthesizes the paper's §3 production week
//! (scaled), schedules every startup of every job over a finite GPU pool,
//! replays them in parallel with shared-service contention, and prints
//! Figures 1/3/4/5 data plus the scheduler-derived queue-wait distribution.
//!
//!     cargo run --release --example cluster_week
//!     BOOTSEER_TRACE_JOBS=2800 cargo run --release --example cluster_week

use bootseer::figures;
use bootseer::util::{human, stats};

fn main() {
    let n_jobs = figures::default_trace_jobs();
    println!(
        "synthesizing a cluster week: {n_jobs} jobs (paper: 28,000+; scale with BOOTSEER_TRACE_JOBS)\n"
    );

    let r = figures::week_replay(1);
    println!("-- Fig 1: GPU-hours split --\n{}", figures::fig01(&r).render());
    println!("-- Fig 3a/3b: startup overhead vs scale --\n{}", figures::fig03(&r).render());
    println!("-- Fig 4: startups per job --\n{}", figures::fig04(&r).render());
    println!("-- Fig 5: stage breakdown --\n{}", figures::fig05(&r).render());

    // The replay's queue waits are no longer sampled: phase 1 ran the
    // event-driven chain scheduler (priority + FIFO, no backfill, periodic
    // allocation rounds) over a demand-sized pool, so the distribution
    // below *emerges* from contention — compare it against the paper's
    // "~100 s median, tails of hours" (§3.2).
    println!("-- scheduler: queue waits on a {}-GPU pool --", r.pool_gpus);
    println!(
        "startups {}  median {}  p90 {}  max {}",
        r.queue_waits.len(),
        human::secs(stats::median(&r.queue_waits)),
        human::secs(stats::quantile(&r.queue_waits, 0.9)),
        human::secs(stats::max(&r.queue_waits)),
    );
}
