//! Startup race: the three image-loading engines plus full BootSeer head to
//! head at the paper's largest evaluated scale (128 GPUs), with the record
//! run shown explicitly. Also demonstrates a hot update and straggler
//! statistics.
//!
//!     cargo run --release --example startup_race

use bootseer::config::{BootseerConfig, ClusterConfig, JobConfig};
use bootseer::profiler::Stage;
use bootseer::startup::{run_startup, StartupKind, World};
use bootseer::util::{human, stats};

fn run(label: &str, cfg: &BootseerConfig, world: &mut World, attempt: u32, kind: StartupKind) {
    let job = JobConfig::paper_moe(128);
    let cluster = ClusterConfig::default();
    let o = run_startup(1, attempt, &cluster, &job, cfg, world, kind, 9 + attempt as u64);
    let inst = stats::BoxSummary::of(&o.install_durations);
    println!(
        "{label:<28} image {:>8}  env {:>8}  init {:>8}  | worker total {:>8}  install max/med {:.2}",
        human::secs(o.stage_duration(Stage::ImageLoading)),
        human::secs(o.stage_duration(Stage::EnvSetup)),
        human::secs(o.stage_duration(Stage::ModelInit)),
        human::secs(o.worker_phase_s),
        inst.max / inst.median,
    );
}

fn main() {
    println!("128-GPU (16-node) MoE job, 28.62 GB image, 413 GB checkpoint\n");

    let mut w = World::new();
    run("OCI full pull (strawman)", &BootseerConfig::oci_strawman(), &mut w, 0, StartupKind::Full);

    let mut w = World::new();
    run("lazy loading (baseline)", &BootseerConfig::baseline(), &mut w, 0, StartupKind::Full);

    let cfg = BootseerConfig::bootseer();
    let mut w = World::new();
    run("bootseer: record run", &cfg, &mut w, 0, StartupKind::Full);
    run("bootseer: warm restart", &cfg, &mut w, 1, StartupKind::Full);
    run("bootseer: node-swap restart", &cfg, &mut w, 2, StartupKind::Full);
    run("bootseer: hot update", &cfg, &mut w, 3, StartupKind::HotUpdate);

    println!("\npaper §5: image 4-10x, env 2x, model-init 1.6x, end-to-end ~2x;");
    println!("the record run pays baseline cost once, every restart after that benefits.");
}
