//! END-TO-END VALIDATION (DESIGN.md §6): all layers composing on a real
//! workload.
//!
//! 1. Simulate the cluster startup of a 16-GPU MoE job (baseline vs warm
//!    BootSeer) — the L3 coordinator path.
//! 2. Run the REAL startup code paths that have real-byte engines:
//!    environment-cache capture/restore (archive+RLE over an actual dir) and
//!    striped checkpoint write/read (LocalStore, parallel reader pool).
//! 3. Train the MoE transformer (L2 JAX + L1 Pallas, AOT→HLO→PJRT) for a
//!    few hundred steps from Rust, logging the loss curve; checkpoint
//!    mid-run, resume via striped HDFS-FUSE semantics, continue.
//!
//!     make artifacts && cargo run --release --example train_e2e
//!     BOOTSEER_E2E_STEPS=300 cargo run --release --example train_e2e

use bootseer::config::{BootseerConfig, ClusterConfig, JobConfig};
use bootseer::env::cache::{unpack, CacheCapture};
use bootseer::hdfs::local::LocalStore;
use bootseer::startup::{run_startup, StartupKind, World};
use bootseer::trainer::{SyntheticCorpus, Trainer};
use bootseer::util::{human, json::Json};
use std::time::Instant;

fn main() -> bootseer::util::error::Result<()> {
    let steps: u64 =
        std::env::var("BOOTSEER_E2E_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let artifacts = std::path::PathBuf::from("artifacts");
    bootseer::ensure!(
        artifacts.join("meta.json").exists(),
        "run `make artifacts` first (python AOT pass)"
    );

    // ---- 1. simulated cluster startup (L3) ----
    println!("== phase 1: simulated 16-GPU job startup ==");
    let job = JobConfig::paper_moe(16);
    let cluster = ClusterConfig::default();
    let mut w = World::new();
    let cfg = BootseerConfig::bootseer();
    run_startup(1, 0, &cluster, &job, &cfg, &mut w, StartupKind::Full, 1);
    let warm = run_startup(1, 1, &cluster, &job, &cfg, &mut w, StartupKind::Full, 2);
    let mut w0 = World::new();
    let base = run_startup(
        1,
        0,
        &cluster,
        &job,
        &BootseerConfig::baseline(),
        &mut w0,
        StartupKind::Full,
        2,
    );
    println!(
        "baseline worker phase {} | bootseer (warm) {} | speedup {}\n",
        human::secs(base.worker_phase_s),
        human::secs(warm.worker_phase_s),
        human::ratio(base.worker_phase_s / warm.worker_phase_s)
    );

    // ---- 2. real-bytes startup paths ----
    println!("== phase 2: real env-cache + striped checkpoint engines ==");
    let scratch = std::env::temp_dir().join(format!("bootseer-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    let site = scratch.join("site-packages");
    std::fs::create_dir_all(&site)?;
    let cap = CacheCapture::begin(&site)?;
    std::fs::write(site.join("dep_a.py"), vec![b'a'; 200_000])?;
    std::fs::write(site.join("dep_b.so"), vec![0u8; 400_000])?;
    let archive = cap.finish(3)?;
    println!(
        "env cache captured: 600000 B of installs → {} compressed",
        human::bytes(archive.len() as u64)
    );
    let node2 = scratch.join("replacement-node");
    std::fs::create_dir_all(&node2)?;
    let restored = unpack(&archive, &node2)?;
    println!("restored {} files on replacement node (skipping pip entirely)\n", restored.len());

    // ---- 3. real training over PJRT ----
    println!("== phase 3: train MoE transformer via AOT HLO on PJRT ==");
    let client = xla::PjRtClient::cpu().map_err(|e| bootseer::anyhow!("{e:?}"))?;
    let mut t = Trainer::new(&client, &artifacts, 42)?;
    println!(
        "model: {} params, {} layers, {} experts (L1 pallas kernel inside), batch {}x{}",
        t.meta.n_params, t.meta.n_layers, t.meta.n_experts, t.meta.batch, t.meta.seq
    );
    let mut corpus = SyntheticCorpus::new(t.meta.vocab, 0.05, 7);
    let store = LocalStore::open(scratch.join("hdfs"))?;
    let t0 = Instant::now();
    let half = steps / 2;
    for s in 1..=half {
        let (tok, tgt) = corpus.batch(t.meta.batch, t.meta.seq);
        let loss = t.train_step(&tok, &tgt)?;
        if s % 25 == 0 || s == 1 {
            println!("step {s:>5}  loss {loss:.4}");
        }
    }
    // Mid-run checkpoint through the striped store (the §4.4 write path).
    t.save(&store, "ckpt", 1_000_000, 4)?;
    println!("checkpointed at step {} (striped, 1 MB chunks, width 4)", t.step);
    for s in half + 1..=steps {
        let (tok, tgt) = corpus.batch(t.meta.batch, t.meta.seq);
        let loss = t.train_step(&tok, &tgt)?;
        if s % 25 == 0 || s == steps {
            println!("step {s:>5}  loss {loss:.4}");
        }
    }
    // Simulated failure → resume from the striped checkpoint and verify.
    let before = t.step;
    t.resume(&store, "ckpt", true)?;
    println!("resumed from step {} (was {before}) via striped parallel read", t.step);
    let (tok, tgt) = corpus.batch(t.meta.batch, t.meta.seq);
    let post = t.train_step(&tok, &tgt)?;
    println!("post-resume step loss {post:.4}");

    let dt = t0.elapsed().as_secs_f64();
    let first = t.loss_log.first().map(|&(_, l)| l).unwrap_or(0.0);
    let min = t.loss_log.iter().map(|&(_, l)| l).fold(f32::INFINITY, f32::min);
    println!(
        "\n{} steps in {} ({:.2} steps/s); loss {:.3} → min {:.3} (uniform = ln({}) = {:.3})",
        t.loss_log.len(),
        human::secs(dt),
        t.loss_log.len() as f64 / dt,
        first,
        min,
        t.meta.vocab,
        (t.meta.vocab as f64).ln()
    );
    // Persist the loss curve for EXPERIMENTS.md.
    let mut j = Json::obj();
    j.set("steps", t.loss_log.iter().map(|&(s, _)| s).collect::<Vec<u64>>());
    j.set(
        "loss",
        Json::Arr(t.loss_log.iter().map(|&(_, l)| Json::Num(l as f64)).collect()),
    );
    std::fs::write("artifacts/loss_curve.json", j.to_pretty())?;
    println!("loss curve → artifacts/loss_curve.json");
    let _ = std::fs::remove_dir_all(&scratch);
    Ok(())
}
