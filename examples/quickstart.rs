//! Quickstart: simulate one 32-GPU MoE job startup under the baseline and
//! under BootSeer (after its record run), and print the stage-by-stage
//! comparison — the library's core loop in ~40 lines.
//!
//!     cargo run --release --example quickstart

use bootseer::config::{BootseerConfig, ClusterConfig, JobConfig};
use bootseer::profiler::Stage;
use bootseer::startup::{run_startup, StartupKind, World};
use bootseer::util::human;

fn main() {
    let job = JobConfig::paper_moe(32); // 32 H800s = 4 nodes, PP=2, DP=2
    let cluster = ClusterConfig::default();

    // Baseline: lazy image loading + on-the-fly pip installs + plain HDFS.
    let mut w0 = World::new();
    let base = run_startup(
        1,
        0,
        &cluster,
        &job,
        &BootseerConfig::baseline(),
        &mut w0,
        StartupKind::Full,
        42,
    );

    // BootSeer: first run records hot blocks + captures the env cache...
    let mut w1 = World::new();
    let cfg = BootseerConfig::bootseer();
    run_startup(1, 0, &cluster, &job, &cfg, &mut w1, StartupKind::Full, 42);
    // ...every subsequent startup (restart, node swap, debug cycle) flies.
    let boot = run_startup(1, 1, &cluster, &job, &cfg, &mut w1, StartupKind::Full, 43);

    println!("32-GPU MoE job — worker-phase startup (queuing excluded):\n");
    let mut rows = vec![vec![
        "stage".to_string(),
        "baseline".to_string(),
        "bootseer".to_string(),
        "speedup".to_string(),
    ]];
    for s in [Stage::ImageLoading, Stage::EnvSetup, Stage::ModelInit] {
        rows.push(vec![
            s.name().to_string(),
            human::secs(base.stage_duration(s)),
            human::secs(boot.stage_duration(s)),
            human::ratio(base.stage_duration(s) / boot.stage_duration(s).max(1e-9)),
        ]);
    }
    rows.push(vec![
        "TOTAL".to_string(),
        human::secs(base.worker_phase_s),
        human::secs(boot.worker_phase_s),
        human::ratio(base.worker_phase_s / boot.worker_phase_s),
    ]);
    println!("{}", human::table(&rows));
    println!("paper §5.2: BootSeer reduces end-to-end startup by ~2x.");
}
