//! Fig 6: install-script Max/Median ratio vs job scale.
//! Paper: ~1.0 small → ~1.5 at 1,000+ GPUs, extremes 4x+.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "Fig 6 — straggler Max/Median vs scale",
        "~1.0 small → ~1.5 at 1000+ GPUs (tail 4x)",
    );
    let mut b = Bench::new("fig06");
    let mut out = None;
    b.once("scale_sweep(5 seeds x 6 scales)", || {
        out = Some(bootseer::figures::fig06(5));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
    let _ = figures::default_trace_jobs();
}
