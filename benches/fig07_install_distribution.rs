//! Fig 7: install-duration distribution across 1,440 nodes (11,520 GPUs).
//! Paper: most nodes ≤60s, <1% up to ~92s; everyone waits for the slowest.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "Fig 7 — 11,520-GPU job install durations",
        "long tail: most ≤60s, <1% near 92s",
    );
    let mut b = Bench::new("fig07");
    let mut out = None;
    b.once("run_startup(1440 nodes)", || {
        out = Some(figures::fig07(2));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
}
