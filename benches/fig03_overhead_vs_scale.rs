//! Fig 3a/3b: job-level and node-level startup overhead vs job scale.
//! Paper: >100-GPU jobs start in ~6-7 min; node-level ≈1 min lower.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "Fig 3a/3b — startup overhead vs job scale",
        ">100-GPU jobs ≈6-7 min job-level; node-level ~1 min lower",
    );
    let mut b = Bench::new("fig03");
    let mut out = None;
    b.once("week_replay+fig03", || {
        let r = figures::week_replay(1);
        out = Some(figures::fig03(&r));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
}
