//! Fig 4: number of startup events per job + job counts, by scale.
//! Paper: small jobs ≈1 startup; large jobs 2-8, worst 20+.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header("Fig 4 — startups per job vs scale", "small ≈1; large 2-8; tail 20+");
    let mut b = Bench::new("fig04");
    let mut out = None;
    b.once("week_replay+fig04", || {
        let r = figures::week_replay(1);
        out = Some(figures::fig04(&r));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
}
