//! Fig 5: node-level startup broken down by stage.
//! Paper bands: queuing ~100s, alloc seconds, image 20-40s, env 100-300s,
//! model-init 100-200s.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "Fig 5 — per-stage node-level breakdown",
        "image 20-40s; env 100-300s (dominant); init 100-200s",
    );
    let mut b = Bench::new("fig05");
    let mut out = None;
    b.once("week_replay+fig05", || {
        let r = figures::week_replay(1);
        out = Some(figures::fig05(&r));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
}
