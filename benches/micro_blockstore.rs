//! Hot-path microbench: content-addressed block store (sha256 + dedup) —
//! the substrate behind flattened image layouts.
use bootseer::image::blockstore::BlockStore;
use bootseer::util::bench::Bench;
use bootseer::util::rng::Rng;

fn main() {
    let mut rng = Rng::seeded(2);
    let mb = 64;
    let unique: Vec<u8> = (0..mb * 1_000_000).map(|_| rng.next_u64() as u8).collect();
    let dup = vec![7u8; mb * 1_000_000];

    let mut b = Bench::new("micro_blockstore");
    b.iter(&format!("put_unique_{mb}MB_4MB_blocks"), || {
        let mut s = BlockStore::new();
        s.put_chunked(&unique, 4_000_000);
        s.physical_bytes
    });
    b.iter(&format!("put_dup_{mb}MB_4MB_blocks"), || {
        let mut s = BlockStore::new();
        s.put_chunked(&dup, 4_000_000);
        assert!(s.dedup_ratio() > 10.0);
        s.physical_bytes
    });
    b.iter("roundtrip_16MB", || {
        let mut s = BlockStore::new();
        let ds = s.put_chunked(&unique[..16_000_000], 1_000_000);
        s.get_chunked(&ds).unwrap().len()
    });
    b.finish();
}
