//! Fig 1: GPU-server-hours split (training vs startup) over a cluster day.
//! Paper claim: >3.5% of GPU time wasted on startup alone.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "Fig 1 — cluster GPU-hours: training vs startup",
        ">3.5% of GPU time wasted on startup",
    );
    let mut b = Bench::new("fig01");
    let mut out = None;
    b.once("week_replay+fig01", || {
        let r = figures::week_replay(1);
        out = Some(figures::fig01(&r));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
}
