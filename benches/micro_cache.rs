//! Fleet cache-economics sweep: one synthetic week replayed per per-node
//! cache capacity (LRU eviction) under storm-tier fault traffic — finite
//! registry/cluster-cache concurrency slots (deterministic load-shedding
//! plus seeded retry backoff) and a hot crash hazard that keeps warm
//! restarts hitting partially evicted caches. Emits `BENCH_cache.json`
//! so the capacity knee curve — fleet wasted fraction vs cache size —
//! is tracked across PRs (CI diffs it against `benches/baselines/`).
//!
//! Headline: wasted GPU time strictly falls as the cache grows and
//! plateaus at the unbounded endpoint; hit rate rises with capacity
//! while the shed rate stays a property of the admission limits, not of
//! the cache size.
//!
//!     cargo bench --bench micro_cache
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench micro_cache

use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "cache economics: capacity knee under storm faults",
        "wasted fraction strictly falls with cache capacity, plateaus unbounded",
    );
    let faults = figures::cache_sweep_faults();
    println!("faults: {}", faults.describe());
    let mut b = Bench::new("micro_cache");
    let mut out = None;
    b.once(
        &format!(
            "{}-job week x {} capacities",
            figures::CACHE_SWEEP_JOBS,
            figures::CACHE_SWEEP_CAPACITIES.len()
        ),
        || {
            out = Some(figures::cache_economics_sweep(
                figures::FAULTS_SWEEP_SEED,
                figures::CACHE_SWEEP_JOBS,
                &faults,
            ));
        },
    );
    let sweep = out.unwrap();
    println!("\n{}", sweep.render());
    let path = "BENCH_cache.json";
    match std::fs::write(path, sweep.to_json().to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Machine-checkable acceptance invariants.
    let restarts = sweep.points[0].fault_restarts;
    assert!(restarts > 0, "storm-tier sweep must fire restarts");
    for p in &sweep.points {
        assert_eq!(
            p.fault_restarts, restarts,
            "crash schedule must not depend on cache capacity ({})",
            p.capacity
        );
    }
    for w in sweep.points.windows(2) {
        assert!(
            w[1].wasted_fraction < w[0].wasted_fraction,
            "knee must strictly fall: {} {} vs {} {}",
            w[0].capacity,
            w[0].wasted_fraction,
            w[1].capacity,
            w[1].wasted_fraction
        );
    }
    let unbounded = sweep.point("unbounded");
    assert_eq!(unbounded.evicted_bytes, 0, "unbounded cache never evicts");
    assert!(
        sweep.point("3g").hit_rate < unbounded.hit_rate,
        "hit rate must rise from the smallest cache to unbounded"
    );
    b.finish();
}
