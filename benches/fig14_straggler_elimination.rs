//! Fig 14: install-duration distribution across the 128-GPU job's nodes,
//! baseline vs BootSeer. Paper: BootSeer removes overhead AND spread.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "Fig 14 — env-cache straggler elimination (128 GPUs)",
        "BootSeer flattens the install-time distribution",
    );
    let mut b = Bench::new("fig14");
    let mut out = None;
    b.iter("baseline+bootseer 128-GPU startups", || {
        out = Some(figures::fig14(3));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
}
