//! Placement-fragmentation sweep: the warm 128-GPU startup re-run with its
//! 16 nodes spread over 1, 2, 4, 8, and 16 racks of a 16-rack / 4-spine
//! tree whose spine core is oversubscribed 10x against the node NICs (rack
//! uplinks stay inert so only the cross-rack share of the swarm traffic
//! binds). Emits `BENCH_topology.json` so the fragmentation tax — startup
//! time vs gang spread — is tracked across PRs (CI diffs it against
//! `benches/baselines/`).
//!
//! Headline: warm startup time strictly increases with the number of racks
//! the gang spans, because each extra rack converts in-rack swarm peers
//! into cross-spine peers that share the oversubscribed core tier.
//!
//!     cargo bench --bench micro_topology
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench micro_topology

use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

/// Seed shared with the `fragmentation_sweep_strictly_increases_and_reproduces`
/// unit test and the `figures` subcommand, so all three emit the same curve.
const SWEEP_SEED: u64 = 7;

fn main() {
    figure_header(
        "topology: fragmentation tax at 128 GPUs",
        "warm startup strictly slows as the gang spreads across racks",
    );
    let mut b = Bench::new("micro_topology");
    let mut out = None;
    b.once(
        &format!("128-GPU warm startup x {} spreads", figures::FRAG_SWEEP_RACKS.len()),
        || {
            out = Some(figures::fragmentation_sweep(SWEEP_SEED));
        },
    );
    let sweep = out.unwrap();
    println!("\n{}", sweep.render());
    let path = "BENCH_topology.json";
    match std::fs::write(path, sweep.to_json().to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Machine-checkable acceptance invariants.
    assert_eq!(sweep.points.len(), figures::FRAG_SWEEP_RACKS.len());
    let first = &sweep.points[0];
    let last = &sweep.points[sweep.points.len() - 1];
    assert_eq!(first.cross_frac, 0.0, "one rack means zero cross-spine peers");
    assert_eq!(last.cross_frac, 1.0, "16 racks means every peer is cross-spine");
    for w in sweep.points.windows(2) {
        assert!(
            w[1].worker_s > w[0].worker_s && w[1].total_s > w[0].total_s,
            "fragmentation tax must be strictly increasing: {} racks {:.3}s vs {} racks {:.3}s",
            w[0].racks_spanned,
            w[0].total_s,
            w[1].racks_spanned,
            w[1].total_s
        );
    }
    b.finish();
}
