//! Fleet-scale replay microbench: the event-driven gang scheduler and the
//! epoch-sharded two-phase replay, with the byte-identity guarantees
//! asserted at bench scale.
//!
//! Cases:
//!
//! * `replay_*jobs_{1,N}thread` — the original week replay at 1 thread vs
//!   all cores; results must be byte-identical.
//! * `sched_*chains_{event,reference}` — the event-driven scheduler vs the
//!   preserved round-grid [`reference`] engine on one synthetic chain
//!   workload; outcomes bit-compared, and the runtime ratio lands in
//!   `BENCH_replay.json` (`runtime_vs_reference_fraction`, lower is
//!   better), regression-gated against
//!   `benches/baselines/BENCH_replay.json` in CI.
//! * `fleet_schedule_*jobs` — phase 1 alone over a 365-day trace at the
//!   paper's fleet pool (131,072 GPUs; 2M jobs in full mode, 100k fast) —
//!   the scale the round-grid scheduler could not reach in bench time.
//! * `fleet_year_replay_*jobs` — the full two-phase replay over a 365-day
//!   horizon, epoch-sharded one epoch per simulated day; byte-identity is
//!   asserted across thread counts AND epoch counts (1 epoch ≡ the
//!   pre-sharding replay).
//!
//!     cargo bench --bench micro_replay_parallel
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench micro_replay_parallel
//!
//! [`reference`]: bootseer::scheduler::reference

use bootseer::config::defaults::SCHED_ROUND_S;
use bootseer::config::{BootseerConfig, ClusterConfig};
use bootseer::figures::fleet_replay;
use bootseer::scheduler::reference::schedule_chains_reference;
use bootseer::scheduler::{schedule_chains_with, ChainJob, ChainOutcome};
use bootseer::trace::{gen_trace, replay_cluster, schedule_trace, ReplayOptions, ReplayResult};
use bootseer::util::bench::{figure_header, Bench};
use bootseer::util::json::Json;
use bootseer::util::rng::mix64;
use bootseer::util::stats;

fn fold(h: u64, v: u64) -> u64 {
    mix64(h ^ v)
}

/// Order-sensitive digest of a schedule — any bit of any segment differing
/// between the two engines changes it.
fn sched_digest(outs: &[ChainOutcome]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for o in outs {
        h = fold(h, o.id);
        h = fold(h, o.gpus as u64);
        for s in &o.segments {
            h = fold(h, s.start_s.to_bits());
            h = fold(h, s.end_s.to_bits());
            h = fold(h, s.queue_wait_s.to_bits());
            h = fold(h, s.interrupted as u64);
            h = fold(h, s.lost_train_s.to_bits());
        }
    }
    h
}

/// Digest of a replay result: every queue wait plus all aggregate
/// counters, bit-exact.
fn replay_digest(r: &ReplayResult) -> u64 {
    let mut h = 0x0100_0000_01b3u64;
    for &w in &r.queue_waits {
        h = fold(h, w.to_bits());
    }
    for v in [
        r.startup_gpu_hours.to_bits(),
        r.train_gpu_hours.to_bits(),
        r.lost_train_gpu_hours.to_bits(),
        r.fault_restarts,
        r.pool_gpus as u64,
        r.credited_bytes,
        r.demanded_bytes,
        r.shed_events,
        r.shed_checks,
        r.evicted_bytes,
    ] {
        h = fold(h, v);
    }
    h
}

/// Deterministic synthetic chain workload: power-of-two gang sizes skewed
/// small, 1–3 segments, submits spread over a year. Sized so the pool sees
/// real queueing (busy periods with a pending set for the reference
/// engine's passes to rescan).
fn synth_chains(n: usize) -> Vec<ChainJob> {
    (0..n as u64)
        .map(|i| {
            let h = mix64(0xF1EE7 ^ i);
            let gpus = 8u32 << (h % 6);
            let submit_s = (mix64(h) % (365 * 86_400)) as f64;
            let segs = 1 + (mix64(h ^ 1) % 3) as usize;
            let hold_s = 1_800.0 + (mix64(h ^ 2) % 86_400) as f64;
            ChainJob {
                id: i,
                submit_s,
                gpus,
                priority: ((h >> 32) % 4) as u32,
                segments: vec![hold_s; segs],
            }
        })
        .collect()
}

fn main() {
    figure_header(
        "micro — fleet-scale replay",
        "event-driven scheduling + epoch-sharded replay reach fleet-year scale, byte-identical",
    );
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut b = Bench::new("micro_replay_parallel");

    // ---- week replay: 1 thread vs all cores, byte-identical ----
    let n_jobs = if fast { 60 } else { 300 };
    let trace = gen_trace(1, n_jobs, 7.0 * 86400.0);
    let cluster = ClusterConfig::default();
    let cfg = BootseerConfig::baseline();
    let week_opts = |threads: usize| ReplayOptions { threads, ..ReplayOptions::default() };
    let mut dig_seq = 0u64;
    b.iter(&format!("replay_{n_jobs}jobs_1thread"), || {
        let r = replay_cluster(&trace, &cluster, &cfg, 1, &week_opts(1));
        dig_seq = replay_digest(&r);
        dig_seq
    });
    let mut dig_par = 0u64;
    b.iter(&format!("replay_{n_jobs}jobs_{cores}threads"), || {
        let r = replay_cluster(&trace, &cluster, &cfg, 1, &week_opts(0));
        dig_par = replay_digest(&r);
        dig_par
    });
    assert_eq!(dig_seq, dig_par, "parallel replay must be byte-identical to sequential");

    // ---- scheduler: event-driven vs round-grid reference ----
    let n_chains = if fast { 8_000 } else { 50_000 };
    let chains = synth_chains(n_chains);
    let pool = (n_chains as u32 / 1_000).max(1) * 512;
    let mut dig_new = 0u64;
    let new_s = b.iter(&format!("sched_{n_chains}chains_event"), || {
        let outs = schedule_chains_with(pool, &chains, SCHED_ROUND_S, None);
        dig_new = sched_digest(&outs);
        dig_new
    });
    let mut dig_ref = 0u64;
    let ref_s = b.iter(&format!("sched_{n_chains}chains_reference"), || {
        let outs = schedule_chains_reference(pool, &chains, SCHED_ROUND_S, None);
        dig_ref = sched_digest(&outs);
        dig_ref
    });
    assert_eq!(dig_new, dig_ref, "event-driven scheduler must match the reference bit-for-bit");
    let speedup = ref_s / new_s;
    println!(
        "\nscheduler {n_chains} chains over {pool} GPUs: event {new_s:.3}s vs \
         reference {ref_s:.3}s → {speedup:.1}x"
    );

    // ---- phase 1 alone at fleet scale (the pool the paper's fleet ran) ----
    let n_fleet = if fast { 100_000 } else { 2_000_000 };
    let fleet_trace = gen_trace(7, n_fleet, 365.0 * 86400.0);
    let mut waits: Vec<f64> = Vec::new();
    let mut segments = 0u64;
    let sched_wall = b.once(&format!("fleet_schedule_{n_fleet}jobs"), || {
        let s = schedule_trace(&fleet_trace, &cluster, Some(131_072));
        waits = s
            .outcomes
            .iter()
            .flat_map(|o| o.segments.iter().map(|seg| seg.queue_wait_s))
            .collect();
        segments = waits.len() as u64;
        segments
    });
    let wait_median = stats::median(&waits);
    println!(
        "fleet schedule: {n_fleet} jobs / {segments} segments over 131072 GPUs in \
         {sched_wall:.2}s wall (median queue wait {wait_median:.0}s)"
    );

    // ---- fleet-year two-phase replay, epoch-sharded ----
    let n_year = if fast { 150 } else { 4_000 };
    // Baseline: 1 thread, 1 epoch — structurally the pre-sharding replay.
    let mut dig_base = 0u64;
    b.once(&format!("fleet_year_replay_{n_year}jobs_presharding"), || {
        dig_base = replay_digest(&fleet_replay(7, n_year, 1, 1));
        dig_base
    });
    // Measured point: all cores, auto-sharded one epoch per simulated day.
    let mut year = None;
    let year_wall = b.once(&format!("fleet_year_replay_{n_year}jobs_epoched"), || {
        let r = fleet_replay(7, n_year, 0, 0);
        let d = replay_digest(&r);
        year = Some(r);
        d
    });
    let year = year.expect("measured fleet-year run");
    assert_eq!(
        replay_digest(&year),
        dig_base,
        "epoch-sharded parallel replay must be byte-identical to the pre-sharding replay"
    );
    // Odd epoch count, all cores — partition boundaries may not touch bits.
    let dig_13 = replay_digest(&fleet_replay(7, n_year, 0, 13));
    assert_eq!(dig_13, dig_base, "replay must be byte-identical at any epoch count");
    println!(
        "fleet-year replay: {n_year} jobs, 365-day horizon, daily epochs in {year_wall:.2}s \
         wall — byte-identical across threads and epoch counts"
    );

    // ---- BENCH_replay.json (gated against benches/baselines/) ----
    let mut ratio_case = Json::obj();
    ratio_case
        .set("chains", n_chains as u64)
        .set("pool_gpus", pool as u64)
        .set("speedup_x", speedup)
        // The gated metric (lower is better): fraction of the reference
        // engine's runtime the event-driven engine needs — machine-neutral.
        .set("runtime_vs_reference_fraction", new_s / ref_s);
    let mut sched_case = Json::obj();
    sched_case
        .set("jobs", n_fleet as u64)
        .set("pool_gpus", 131_072u64)
        .set("segments", segments)
        .set("jobs_per_wallsec", n_fleet as f64 / sched_wall)
        // Gated: simulated seconds, deterministic for a given seed/scale.
        .set("queue_wait_median_s", wait_median);
    let mut year_case = Json::obj();
    year_case
        .set("jobs", n_year as u64)
        .set("horizon_days", 365u64)
        .set("pool_gpus", year.pool_gpus as u64)
        .set("jobs_per_wallsec", n_year as f64 / year_wall)
        // Gated: overhead quantities of the simulated fleet year.
        .set("startup_fraction", year.startup_fraction())
        .set("startup_gpu_hours", year.startup_gpu_hours);
    let mut j = Json::obj();
    j.set("scheduler_ratio", ratio_case);
    j.set("fleet_schedule", sched_case);
    j.set("fleet_year_replay", year_case);
    j.set("fast", fast);
    let path = "BENCH_replay.json";
    match std::fs::write(path, j.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Sanity floor (the gate enforces the real bar via the baseline).
    assert!(
        new_s <= ref_s * 1.5,
        "event-driven scheduler slower than the round-grid reference: \
         {new_s:.3}s vs {ref_s:.3}s"
    );
    b.finish();
}
