//! Parallel cluster-replay microbench: the two-phase contention-aware
//! replay (scheduler + parallel startup simulation) at 1 thread vs all
//! cores, verifying the speedup is real and the result identical.
//!
//!     cargo bench --bench micro_replay_parallel
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench micro_replay_parallel

use bootseer::config::{BootseerConfig, ClusterConfig};
use bootseer::trace::{gen_trace, replay_cluster, ReplayOptions};
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "micro — parallel cluster replay",
        "phase 2 scales across cores; results byte-identical at any thread count",
    );
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let n_jobs = if fast { 60 } else { 300 };
    let trace = gen_trace(1, n_jobs, 7.0 * 86400.0);
    let cluster = ClusterConfig::default();
    let cfg = BootseerConfig::baseline();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let mut b = Bench::new("micro_replay_parallel");
    let mut hours_seq = 0.0;
    b.iter(&format!("replay_{n_jobs}jobs_1thread"), || {
        let r = replay_cluster(
            &trace,
            &cluster,
            &cfg,
            1,
            &ReplayOptions { pool_gpus: None, threads: 1, ..ReplayOptions::default() },
        );
        hours_seq = r.startup_gpu_hours;
        r.startup_gpu_hours
    });
    let mut hours_par = 0.0;
    b.iter(&format!("replay_{n_jobs}jobs_{cores}threads"), || {
        let r = replay_cluster(
            &trace,
            &cluster,
            &cfg,
            1,
            &ReplayOptions { pool_gpus: None, threads: 0, ..ReplayOptions::default() },
        );
        hours_par = r.startup_gpu_hours;
        r.startup_gpu_hours
    });
    assert_eq!(
        hours_seq.to_bits(),
        hours_par.to_bits(),
        "parallel replay must be byte-identical to sequential"
    );
    println!("\ndeterminism check passed: {hours_seq} GPU-hours on both paths");
    b.finish();
}
