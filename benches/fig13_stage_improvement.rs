//! Fig 13: per-stage breakdown of Fig 12's runs.
//! Paper: image 4-10x (growing with scale), env 2x, model-init 1.6x.
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header("Fig 13 — per-stage improvement", "image 4-10x; env 2x; model-init 1.6x");
    let mut b = Bench::new("fig13");
    let mut out = None;
    b.once("scales x 3 reps x stages", || {
        out = Some(figures::fig12(3));
    });
    println!("\n{}", out.unwrap().render_stages());
    b.finish();
}
