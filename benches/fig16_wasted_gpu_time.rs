//! Wasted-GPU-time sweep under fault injection (Fig 16): one synthetic
//! week replayed per stage-graph overlap mode with the production-
//! calibrated fault processes (`FaultConfig::paper`) — seeded crash
//! hazard, checkpoint rollback, warm/cold restarts, brownouts, injected
//! stragglers. Emits `BENCH_faults.json` so the wasted-GPU-time trajectory
//! is tracked across PRs (CI diffs it against `benches/baselines/`).
//!
//! Paper anchor: "more than 3.5% of GPU time is wasted due to startup
//! overhead alone" — the Sequential/baseline point must land in the 2–5%
//! band, and the Speculative mitigation must waste strictly less on the
//! 128+-GPU jobs.
//!
//!     cargo bench --bench fig16_wasted_gpu_time
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench fig16_wasted_gpu_time

use bootseer::config::OverlapMode;
use bootseer::faults::FaultConfig;
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "fig 16: wasted GPU time under fault injection",
        ">3.5% of GPU time wasted at baseline; overlap mitigations cut it",
    );
    let faults = FaultConfig::paper();
    println!("faults: {}", faults.describe());
    let mut b = Bench::new("fig16_wasted_gpu_time");
    let mut out = None;
    b.once(
        &format!("{}-job week x 3 modes", figures::FAULTS_SWEEP_JOBS),
        || {
            out = Some(figures::wasted_gpu_time_sweep(
                figures::FAULTS_SWEEP_SEED,
                figures::FAULTS_SWEEP_JOBS,
                &faults,
            ));
        },
    );
    let sweep = out.unwrap();
    println!("\n{}", sweep.render());
    let path = "BENCH_faults.json";
    match std::fs::write(path, sweep.to_json().to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Machine-checkable acceptance invariants.
    let seq = sweep.point(OverlapMode::Sequential);
    let spec = sweep.point(OverlapMode::Speculative);
    assert!(
        (0.02..=0.05).contains(&seq.wasted_fraction),
        "baseline wasted fraction {} outside the paper's 2-5% band",
        seq.wasted_fraction
    );
    assert!(
        spec.wasted_fraction_ge128 < seq.wasted_fraction_ge128,
        "speculative must waste strictly less at 128+ GPUs: {} vs {}",
        spec.wasted_fraction_ge128,
        seq.wasted_fraction_ge128
    );
    assert!(
        spec.wasted_fraction < seq.wasted_fraction,
        "speculative must waste strictly less overall: {} vs {}",
        spec.wasted_fraction,
        seq.wasted_fraction
    );
    b.finish();
}
