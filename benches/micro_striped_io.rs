//! Hot-path microbench: real striped store, parallel vs sequential read
//! (the §4.4 mechanism on an actual filesystem) across stripe widths.
use bootseer::hdfs::local::LocalStore;
use bootseer::util::bench::Bench;
use bootseer::util::rng::Rng;

fn main() {
    let dir = std::env::temp_dir().join(format!("bootseer-bench-io-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = LocalStore::open(&dir).unwrap();
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let mb = if fast { 64 } else { 256 };
    let mut rng = Rng::seeded(1);
    let data: Vec<u8> = (0..mb * 1_000_000).map(|_| rng.next_u64() as u8).collect();

    let mut b = Bench::new("micro_striped_io");
    for width in [1u32, 2, 4, 8] {
        store.write_striped(&format!("ckpt_w{width}"), &data, 1_000_000, width).unwrap();
    }
    b.iter(&format!("write_striped_w4_{mb}MB"), || {
        store.write_striped("ckpt_wr", &data, 1_000_000, 4).unwrap();
    });
    b.iter(&format!("read_sequential_{mb}MB"), || {
        store.read_sequential("ckpt_w4").unwrap().len()
    });
    for width in [1u32, 2, 4, 8] {
        b.iter(&format!("read_parallel_w{width}_{mb}MB"), || {
            store.read_striped_parallel(&format!("ckpt_w{width}")).unwrap().len()
        });
    }
    b.finish();
    let _ = std::fs::remove_dir_all(&dir);
}
