//! Batched what-if evaluation microbench: K=32 candidate configurations
//! replayed over one synthetic week through `batch_replay` (one shared
//! replay prefix + per-effective-config evaluation) vs K independent
//! `replay_cluster` calls. Every candidate's result is bit-compared
//! against its standalone replay, then a quick closed-loop optimize
//! search runs end-to-end. Emits `BENCH_optimize.json`; CI gates
//! `batch_vs_naive_fraction` (lower is better) against
//! `benches/baselines/`.
//!
//! Headline: the batched engine evaluates the 32-candidate grid for a
//! small fraction (<=1/5) of the naive cost — the grid collapses to one
//! prefix build plus 4 effective evaluations (speculative budgets and
//! cache knobs are provably dead here: non-speculative modes and a
//! fault-free trace with dedup off), and every result is byte-identical
//! to its standalone replay.
//!
//!     cargo bench --bench micro_optimize
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench micro_optimize

use bootseer::config::{BootseerConfig, CachePolicy, ClusterConfig, OverlapMode};
use bootseer::optimize::{run_optimize, OptimizeParams};
use bootseer::trace::{batch_replay, gen_trace, replay_cluster, ReplayOptions, ReplayResult};
use bootseer::util::bench::{figure_header, Bench};
use bootseer::util::json::Json;
use bootseer::util::rng::mix64;

fn fold(h: u64, v: u64) -> u64 {
    mix64(h ^ v)
}

/// Digest of a replay result: every queue wait plus all aggregate
/// counters, bit-exact.
fn replay_digest(r: &ReplayResult) -> u64 {
    let mut h = 0x0100_0000_01b3u64;
    for &w in &r.queue_waits {
        h = fold(h, w.to_bits());
    }
    for v in [
        r.startup_gpu_hours.to_bits(),
        r.train_gpu_hours.to_bits(),
        r.lost_train_gpu_hours.to_bits(),
        r.fault_restarts,
        u64::from(r.pool_gpus),
        r.credited_bytes,
        r.demanded_bytes,
        r.shed_events,
        r.shed_checks,
        r.evicted_bytes,
    ] {
        h = fold(h, v);
    }
    h
}

/// The K=32 what-if grid: overlap x delta-resume x cache capacity x
/// cache policy x speculative budget. Fault-free and dedup-off on
/// purpose — the cache and budget axes are provably dead, so the batched
/// engine should collapse the grid to 4 effective evaluations.
fn candidate_grid() -> Vec<ReplayOptions> {
    let mut cands = Vec::new();
    for &overlap in &[OverlapMode::Sequential, OverlapMode::Overlapped] {
        for &delta in &[false, true] {
            for &capacity in &[24_000_000_000u64, 8_000_000_000] {
                for &policy in &[CachePolicy::Lru, CachePolicy::Gdsf] {
                    for &budget in &[4_000_000_000u64, 8_000_000_000] {
                        cands.push(
                            ReplayOptions::new()
                                .with_overlap(overlap)
                                .with_delta_resume(delta)
                                .with_cache(capacity, policy)
                                .with_spec_prefetch_budget(budget),
                        );
                    }
                }
            }
        }
    }
    cands
}

fn main() {
    figure_header(
        "micro — batched what-if evaluation",
        "32 candidate configs replay for <=1/5 the cost of 32 independent replays, bit-identical",
    );
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let mut b = Bench::new("micro_optimize");

    let seed = 11u64;
    let n_jobs = if fast { 16 } else { 40 };
    let trace = gen_trace(seed, n_jobs, 7.0 * 86400.0);
    let cluster = ClusterConfig::default();
    let cfg = BootseerConfig::bootseer();
    let cands = candidate_grid();
    let k = cands.len();
    assert_eq!(k, 32, "the headline grid is K=32");

    // ---- naive: K independent full replays ----
    let mut naive_digests = Vec::new();
    let naive_wall = b.once(&format!("naive: {k} independent replay_cluster calls"), || {
        naive_digests = cands
            .iter()
            .map(|c| replay_digest(&replay_cluster(&trace, &cluster, &cfg, seed, c)))
            .collect();
        naive_digests.len()
    });

    // ---- batched: one shared prefix, deduped evaluations ----
    let mut out = None;
    let batch_wall = b.once(&format!("batched: one batch_replay over {k} candidates"), || {
        out = Some(batch_replay(&trace, &cluster, &cfg, seed, &cands, 0));
        k
    });
    let out = out.expect("batched run");
    let batch_digests: Vec<u64> = out.results.iter().map(replay_digest).collect();
    assert_eq!(
        naive_digests, batch_digests,
        "every batched candidate must be byte-identical to its standalone replay"
    );
    assert_eq!(out.prefix_builds, 1, "one prefix-relevant setting → one prefix build");
    assert_eq!(
        out.eval_groups, 4,
        "dead cache/budget axes must collapse the grid to overlap x delta"
    );
    let fraction = batch_wall / naive_wall;
    println!(
        "\nbatched {k} candidates: {batch_wall:.3}s vs naive {naive_wall:.3}s \
         → {:.1}x cheaper (fraction {fraction:.3}; 1 prefix build, {} evaluations)",
        naive_wall / batch_wall,
        out.eval_groups
    );

    // ---- closed-loop search end-to-end (quick ladder) ----
    let mut report = None;
    b.once("optimize: quick successive-halving search", || {
        report = Some(run_optimize(&OptimizeParams::quick(seed, 0)));
        k
    });
    let report = report.expect("search run");
    println!("{}", report.render());

    // ---- BENCH_optimize.json (gated against benches/baselines/) ----
    let mut batch_case = Json::obj();
    batch_case
        .set("k_candidates", k)
        .set("jobs", n_jobs)
        .set("horizon_days", 7u64)
        .set("prefix_builds", out.prefix_builds)
        .set("eval_groups", out.eval_groups)
        .set("naive_wallsec", naive_wall)
        .set("batch_wallsec", batch_wall)
        // The gated metric (lower is better): fraction of the naive
        // K-replay cost the batched engine needs — machine-neutral.
        .set("batch_vs_naive_fraction", fraction);
    let mut search_case = Json::obj();
    search_case
        .set("n_candidates", report.outcomes.len())
        .set("screen_prefix_builds", report.screen_prefix_builds)
        .set("screen_eval_groups", report.screen_eval_groups)
        .set("survivors", report.survivors.len())
        .set("frontier_points", report.frontier.len())
        .set("frontier_min_wasted", report.best_wasted_fraction());
    let mut j = Json::obj();
    j.set("batched_evaluation", batch_case);
    j.set("optimize_search", search_case);
    j.set("fast", fast);
    let path = "BENCH_optimize.json";
    match std::fs::write(path, j.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Sanity floor (the gate enforces the real <=0.20 bar via the
    // baseline): batching must never cost more than half the naive sweep.
    assert!(
        fraction <= 0.5,
        "batched evaluation too close to naive cost: {batch_wall:.3}s vs {naive_wall:.3}s"
    );
    assert!(!report.frontier.is_empty(), "the search must produce a frontier");
    b.finish();
}
