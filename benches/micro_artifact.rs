//! Artifact-layer materialization sweep: cold start vs warm restart vs
//! delta resume (plus the cross-artifact dedup variant) at 16 and 128
//! nodes, through the unified content-addressed transfer plane. Emits
//! `BENCH_artifact.json` (seconds + bytes + byte fractions per scale) so
//! the byte-movement trajectory is tracked across PRs by the bench gate.
//!
//!     cargo bench --bench micro_artifact
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench micro_artifact

use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "artifact-layer sweep — cold / warm / delta materialization",
        "warm and delta restarts re-fetch strictly fewer bytes; dedup serves shared chunks locally",
    );
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let reps = if fast { 1 } else { 3 };
    let mut b = Bench::new("micro_artifact");
    let mut out = None;
    b.once(&format!("2 scales x 4 scenarios x {reps} reps"), || {
        out = Some(figures::artifact_sweep(reps));
    });
    let sweep = out.unwrap();
    println!("\n{}", sweep.render());
    let path = "BENCH_artifact.json";
    match std::fs::write(path, sweep.to_json().to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Machine-checkable invariants, also enforced by the library tests:
    // the dedup/delta scenarios move strictly fewer bytes than cold.
    for p in &sweep.points {
        assert!(p.warm_bytes < p.cold_bytes, "nodes={}", p.nodes);
        assert!(p.delta_bytes < p.warm_bytes, "nodes={}", p.nodes);
        assert!(p.dedup_bytes < p.cold_bytes, "nodes={}", p.nodes);
        assert!(p.warm_s <= p.cold_s + 1e-9, "nodes={}", p.nodes);
        assert!(p.delta_s <= p.warm_s + 1e-9, "nodes={}", p.nodes);
    }
    b.finish();
}
