//! Overlap-mode sweep: worker-phase startup under the three stage-graph
//! gating disciplines (Sequential / Overlapped / Speculative), warm
//! BootSeer configuration, 16→128 GPUs. Emits `BENCH_overlap.json`
//! (mode → worker-phase seconds per scale) so the perf trajectory is
//! tracked across PRs.
//!
//!     cargo bench --bench fig15_overlap_modes
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench fig15_overlap_modes

use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "overlap-mode sweep — startup stage graph",
        "Sequential ≥ Overlapped ≥ Speculative worker phase at every scale",
    );
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let reps = if fast { 1 } else { 3 };
    let mut b = Bench::new("fig15_overlap");
    let mut out = None;
    b.once(&format!("4 scales x 3 modes x {reps} reps"), || {
        out = Some(figures::overlap_sweep(reps));
    });
    let sweep = out.unwrap();
    println!("\n{}", sweep.render());
    let path = "BENCH_overlap.json";
    match std::fs::write(path, sweep.to_json().to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Machine-checkable invariant, also enforced by the library tests.
    for p in &sweep.points {
        assert!(p.worker_s[1] <= p.worker_s[0] + 1e-9, "gpus={}", p.gpus);
        assert!(p.worker_s[2] <= p.worker_s[1] + 1e-9, "gpus={}", p.gpus);
    }
    b.finish();
}
