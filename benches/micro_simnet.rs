//! Hot-path microbench: the fluid-flow engine (events/s) and full startup
//! sims at several scales — the L3 §Perf target (1,440-node startup < 1 s).
//!
//! The headline cases are the churn replay shape (waves of striped reads
//! injected mid-run, per-read stream resources retiring as they finish):
//!
//! * `fluid_churn_ratio_*` runs a bounded instance through BOTH the
//!   current engine and the preserved pre-refactor [`ReferenceSim`]; the
//!   measured ratio lands in `BENCH_simnet.json`
//!   (`runtime_vs_reference_fraction`, lower is better) and is
//!   regression-gated against `benches/baselines/BENCH_simnet.json` in
//!   CI — the O(active)-bounded engine must stay ≥5x faster.
//! * `fluid_churn_20k` runs the full 20k-concurrent-flow / ~2k-resource
//!   instance through the new engine alone (the reference engine is
//!   O(everything ever created) per event and cannot reach this scale in
//!   bench time — which is the point).
//!
//!     cargo bench --bench micro_simnet
//!     BOOTSEER_BENCH_FAST=1 cargo bench --bench micro_simnet
use bootseer::config::{BootseerConfig, ClusterConfig, JobConfig};
use bootseer::sim::golden::churn;
use bootseer::sim::reference::ReferenceSim;
use bootseer::sim::{Capacity, FluidSim};
use bootseer::startup::{run_startup, StartupKind, World};
use bootseer::util::bench::Bench;
use bootseer::util::json::Json;

fn main() {
    let fast = std::env::var("BOOTSEER_BENCH_FAST").ok().as_deref() == Some("1");
    let mut b = Bench::new("micro_simnet");

    // Raw engine: 2,000 flows over 200 shared resources.
    b.iter("fluid_2000flows_200res", || {
        let mut sim = FluidSim::new();
        let res: Vec<_> =
            (0..200).map(|i| sim.add_resource(&format!("r{i}"), Capacity::Fixed(1e9))).collect();
        for i in 0..2000u64 {
            let r = res[(i % 200) as usize];
            sim.flow(1e8, vec![r], &[], i);
        }
        sim.run();
        sim.now()
    });

    for nodes in [16u32, 128, 512, 1440] {
        let job = JobConfig::paper_moe(nodes * 8);
        let cluster = ClusterConfig::default();
        b.iter(&format!("startup_sim_{nodes}nodes"), || {
            let mut w = World::new();
            run_startup(
                1,
                0,
                &cluster,
                &job,
                &BootseerConfig::baseline(),
                &mut w,
                StartupKind::Full,
                1,
            )
            .worker_phase_s
        });
    }

    // ---- churn ratio: new engine vs pre-refactor reference ----
    // Always the bounded 320x8 instance: the reference engine is
    // O(everything ever created) per event, so a 20k-flow run of it would
    // take from minutes to hours per iteration — and the ratio (the gated
    // metric) is scale- and machine-neutral, measured where both engines
    // finish quickly.
    let (rn, rw, rwidth) = (320usize, 2usize, 8usize);
    let mut ratio_events = 0usize;
    let new_s = b.iter("fluid_churn_ratio_new", || {
        let mut sim = FluidSim::new();
        let out = churn(&mut sim, 10, rn, rw, rwidth);
        ratio_events = out.len();
        ratio_events
    });
    let ref_s = b.iter("fluid_churn_ratio_reference", || {
        let mut sim = ReferenceSim::new();
        churn(&mut sim, 10, rn, rw, rwidth).len()
    });
    let speedup = ref_s / new_s;
    let new_meps = ratio_events as f64 / new_s / 1e6;
    let ref_meps = ratio_events as f64 / ref_s / 1e6;
    println!(
        "\nchurn ratio {rn}x{rwidth} ({ratio_events} events): new {new_meps:.3} Mev/s vs \
         reference {ref_meps:.3} Mev/s → {speedup:.1}x"
    );

    // ---- 20k-flow / 2k-resource scale case, new engine only ----
    // 1,000 nodes x 20 parallel striped streams per wave ≈ 20k concurrent
    // flows over ~2k persistent resources (groups, NICs, disks, SCM), with
    // per-read streams injected and retired mid-run. The reference engine
    // cannot reach this scale in bench time — which is the point.
    let (sn, sw, swidth) = (1000usize, 4usize, 20usize);
    let mut scale_events = 0usize;
    let scale_s = b.iter("fluid_churn_20k", || {
        let mut sim = FluidSim::new();
        let out = churn(&mut sim, 10, sn, sw, swidth);
        scale_events = out.len();
        scale_events
    });
    let scale_meps = scale_events as f64 / scale_s / 1e6;
    println!("churn 20k {sn}x{swidth} ({scale_events} events): {scale_meps:.3} Mev/s");

    let mut ratio_case = Json::obj();
    ratio_case
        .set("nodes", rn as u64)
        .set("waves", rw as u64)
        .set("width", rwidth as u64)
        .set("events", ratio_events as u64)
        .set("new_meps", new_meps)
        .set("ref_meps", ref_meps)
        .set("speedup_x", speedup)
        // The gated metric (lower is better): fraction of the reference
        // engine's runtime the new engine needs. A machine-speed-neutral
        // ratio, so the gate tolerance can stay tight.
        .set("runtime_vs_reference_fraction", new_s / ref_s);
    let mut scale_case = Json::obj();
    scale_case
        .set("nodes", sn as u64)
        .set("waves", sw as u64)
        .set("width", swidth as u64)
        .set("events", scale_events as u64)
        .set("new_meps", scale_meps);
    let mut j = Json::obj();
    j.set("churn_ratio", ratio_case);
    j.set("churn_20k", scale_case);
    j.set("fast", fast);
    let path = "BENCH_simnet.json";
    match std::fs::write(path, j.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("write {path}: {e}"),
    }
    // Sanity floor (the gate enforces the real ≥5x bar via the baseline).
    assert!(
        speedup >= 3.0,
        "engine speedup collapsed: {speedup:.2}x vs reference on the churn ratio case"
    );
    b.finish();
}
