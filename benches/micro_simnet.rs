//! Hot-path microbench: the fluid-flow engine (events/s) and full startup
//! sims at several scales — the L3 §Perf target (1,440-node startup < 1 s).
use bootseer::config::{BootseerConfig, ClusterConfig, JobConfig};
use bootseer::sim::{Capacity, FluidSim};
use bootseer::startup::{run_startup, StartupKind, World};
use bootseer::util::bench::Bench;

fn main() {
    let mut b = Bench::new("micro_simnet");

    // Raw engine: 2,000 flows over 200 shared resources.
    b.iter("fluid_2000flows_200res", || {
        let mut sim = FluidSim::new();
        let res: Vec<_> =
            (0..200).map(|i| sim.add_resource(&format!("r{i}"), Capacity::Fixed(1e9))).collect();
        for i in 0..2000u64 {
            let r = res[(i % 200) as usize];
            sim.flow(1e8, vec![r], &[], i);
        }
        sim.run();
        sim.now()
    });

    for nodes in [16u32, 128, 512, 1440] {
        let job = JobConfig::paper_moe(nodes * 8);
        let cluster = ClusterConfig::default();
        b.iter(&format!("startup_sim_{nodes}nodes"), || {
            let mut w = World::new();
            run_startup(
                1,
                0,
                &cluster,
                &job,
                &BootseerConfig::baseline(),
                &mut w,
                StartupKind::Full,
                1,
            )
            .worker_phase_s
        });
    }
    b.finish();
}
