//! Fig 12: end-to-end startup, baseline vs BootSeer, 16→128 GPUs.
//! Paper: ~2x reduction at every scale (3-run average).
use bootseer::figures;
use bootseer::util::bench::{figure_header, Bench};

fn main() {
    figure_header(
        "Fig 12 — end-to-end startup vs scale",
        "BootSeer ≈2x faster at 16..128 GPUs",
    );
    let mut b = Bench::new("fig12");
    let mut out = None;
    b.once("scales x 3 reps x (baseline+bootseer)", || {
        out = Some(figures::fig12(3));
    });
    println!("\n{}", out.unwrap().render());
    b.finish();
}
